//! `stark serve` — the coordinator as a long-running multi-job service.
//!
//! The paper motivates Stark as one step inside larger analytics
//! workflows; this module exposes the multiply engine over a socket as a
//! **job queue**: a leader process owning the simulated cluster + leaf
//! backend, many clients submitting work that interleaves on the shared
//! worker pool under the engine's fair scheduler (each serve job runs as
//! its own engine job via `SparkContext::run_job`, so responses carry
//! only that job's stage metrics).
//!
//! Protocol: newline-delimited JSON over TCP, one request per line, one
//! response line per request. Ops:
//!
//! ```json
//! -> {"op":"ping"}
//! <- {"ok":true,"service":"stark","version":"0.1.0","jobs_inflight":0}
//!
//! // Asynchronous path: submit returns a job id immediately. An
//! // optional "deadline_ms" bounds the job: past it the engine cancels
//! // cleanly and the result document reports the typed timeout. Result
//! // documents carry the fault-tolerance counters ("tasks","attempts",
//! // "recomputed_partitions","speculative_wins" — DESIGN.md S20).
//! -> {"op":"submit","algo":"stark","n":256,"b":4,"seed":7,"deadline_ms":60000}
//! <- {"ok":true,"job_id":3,"status":"queued"}
//! // …or a busy rejection when admission control is at its bound:
//! <- {"ok":false,"busy":true,"error":"server busy: 8 jobs in flight (max 8)"}
//!
//! // Poll without blocking:
//! -> {"op":"status","job_id":3}
//! <- {"ok":true,"job_id":3,"status":"running"}
//! <- {"ok":true,"job_id":3,"status":"done","result":{...}}
//!
//! // Block until completion (optional "timeout_ms"):
//! -> {"op":"wait","job_id":3}
//! <- {"ok":true,"job_id":3,"algo":"stark","wall_ms":12.3,
//!     "stages":[{"label":"divide/L0",...},...],...}
//!
//! // Inspect the queue (finished entries are retained for the last
//! // MAX_FINISHED_JOBS jobs only, so table memory stays bounded):
//! -> {"op":"jobs"}
//! <- {"ok":true,"jobs":[{"job_id":3,"name":"stark n=256 b=4","status":"done"},...]}
//!
//! // Synchronous multiply stays as sugar over submit + wait (subject to
//! // the same admission control; accepts inline "a"/"b_mat" + "return_c"):
//! -> {"op":"multiply","algo":"stark","n":256,"b":4,"seed":7}
//! <- {"ok":true,"job_id":4,"frobenius":148.8,"stages":[...],...}
//!
//! // Submit a whole EXPRESSION instead of one multiply: an "expr" tree
//! // runs as one chained job with a single collect (works on "submit"
//! // and "multiply" alike; node-level "algo"/"b" pin one multiply).
//! // Leaves: {"matrix":[[...]]} (inline) or {"gen":{"n":64,"seed":7}}.
//! // Nodes:  {"mul":[l,r]} {"add":[x,y,...]} {"sub":[x,y]}
//! //         {"scale":[2.0,x]} {"t":x} {"pow":[x,8]}
//! //         {"inv":x} {"solve":[a,b]}
//! // "pow" k may be negative (k < 0 inverts first: x^-k = (x⁻¹)^k);
//! // "inv"/"solve" run the SPIN block recursion (DESIGN.md S23) and
//! // report their level schedules back under "inversions". A
//! // (near-)singular operand fails the job with the typed
//! // "singular matrix" error — never a panic or NaN-poisoned output.
//! -> {"op":"multiply","expr":{"mul":[
//!        {"add":[{"mul":[{"gen":{"n":64,"seed":1}},{"gen":{"n":64,"seed":2}}]},
//!                {"gen":{"n":64,"seed":3}}]},
//!        {"t":{"gen":{"n":64,"seed":4}}}]}}
//! <- {"ok":true,"job_id":5,"algo":"expr","expression":"(A·B+C)·Dᵀ",
//!     "multiplies":[{"label":"m1",...},{"label":"m2",...}],
//!     "collects":1,"stages":[...],...}
//!
//! // Ask the cost-model planner what it WOULD run, without running it.
//! // "algo" and "b" both default to "auto"; "b" also accepts a number:
//! -> {"op":"plan","n":4096}
//! <- {"ok":true,"algorithm":"stark","b":8,"n":4096,
//!     "predicted_wall_ms":123.4,"stages":[...],"considered":[...]}
//!
//! // NAMED MATRICES ([`crate::store`]): upload once, multiply many
//! // times. "put" takes an inline "matrix" or a seeded "gen" and
//! // dedupes identical content by hash; expression leaves (and
//! // "a"/"b_mat") may then be {"ref":"name"}. Every store response —
//! // and every job result — carries the store counters, so cache
//! // behavior (hits/misses/evictions/spills/resident bytes) is
//! // observable per request.
//! -> {"op":"put","name":"W","gen":{"n":256,"seed":7}}
//! <- {"ok":true,"name":"W","rows":256,"cols":256,"bytes":524288,
//!     "deduped":false,"replaced":false,"store":{"hits":0,...}}
//! -> {"op":"multiply","expr":{"mul":[{"ref":"W"},{"ref":"W"}]}}
//! <- {"ok":true,...,"store":{"splits_computed":1,...}}
//! -> {"op":"get","name":"W"}            // metadata; "values":true for the payload
//! <- {"ok":true,"name":"W","rows":256,"cols":256,"resident":true,...}
//! -> {"op":"ls"}
//! <- {"ok":true,"entries":[{"name":"W","rows":256,...}],"store":{...}}
//! -> {"op":"drop","name":"W"}
//! <- {"ok":true,"dropped":true,...}     // or "pinned":true while jobs
//!                                       // still hold it (they finish
//!                                       // unharmed; removal is deferred)
//! // Unknown names/job ids are TYPED rejections, not generic errors:
//! <- {"ok":false,"unknown_name":true,"error":"unknown matrix name 'W'..."}
//! <- {"ok":false,"unknown_job":true,"job_id":99,"error":"unknown job id 99..."}
//! // A dangling ref is caught at submit time by the static analyzer
//! // (STARK-A010), before anything runs.
//!
//! -> {"op":"shutdown"}
//! ```
//!
//! Submitted jobs run through the server's [`StarkSession`]: `"algo"`
//! and `"b"` may each be `"auto"`, in which case the session's planner
//! picks the concrete algorithm/split count (reported back in the
//! result document), and inline matrices of any shape are padded and
//! cropped by the session exactly as for API users.
//!
//! Concurrency model: one handler thread per connection (tracked and
//! joined on [`Server::stop`], with a drain deadline before sockets are
//! force-closed), a bounded FIFO of submitted jobs, and
//! [`ServerState::job_runners`] runner threads executing jobs against
//! the shared cluster. Admission control rejects submits beyond
//! [`ServerState::max_inflight_jobs`] queued + running jobs.
//!
//! Driving the protocol from Rust (ephemeral port, blocking client):
//!
//! ```no_run
//! use stark::api::StarkSession;
//! use stark::cost::Splits;
//! use stark::serve::{request, Server, ServerState};
//! use stark::util::json::Value;
//!
//! let state = ServerState {
//!     session: StarkSession::builder().build()?,
//!     default_splits: Splits::Auto,
//!     max_inflight_jobs: 8,
//!     job_runners: 2,
//! };
//! let mut server = Server::start("127.0.0.1:0", state)?;
//! let addr = server.addr().to_string();
//! let resp = request(&addr, &Value::obj(vec![
//!     ("op", Value::str("multiply")),
//!     ("algo", Value::str("auto")),
//!     ("n", Value::num(128.0)),
//! ]))?;
//! assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
//! server.stop();
//! # Ok::<(), anyhow::Error>(())
//! ```

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::algos::Algorithm;
use crate::api::{DistExpr, DistMatrix, IntoExpr, StarkSession};
use crate::cost::{Plan, Splits};
use crate::error::StarkError;
use crate::matrix::DenseMatrix;
use crate::store::DropOutcome;
use crate::util::json::{self, Value};

/// How long [`Server::stop`] lets in-flight connection handlers finish
/// naturally before force-closing their sockets.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// How many finished (done/failed) jobs the table retains for
/// `status`/`jobs` queries. Older finished entries are evicted as new
/// jobs complete, so table memory is bounded by (admission limit +
/// this window) × result size — not by lifetime request count. Note
/// the window retains full result documents, product matrix included
/// when `return_c` was set (an async submitter must be able to `wait`
/// for it); clients shipping huge products should fetch promptly.
/// `status`/`wait` on an evicted id answers "unknown job id".
const MAX_FINISHED_JOBS: usize = 64;

/// Largest padded matrix edge a request may ask for (the paper's top
/// scale). Caps both `{"n":...}` generation and the pad-and-crop blowup
/// of extreme inline shapes, so one request can't OOM the server.
const MAX_SUBMIT_N: usize = 16_384;

/// Upper clamp on a `wait` request's `timeout_ms` (1 hour). Keeps
/// `Instant + Duration` far from overflow (a u64::MAX timeout would
/// panic the handler) while still being far longer than any job.
const MAX_WAIT_TIMEOUT_MS: u64 = 3_600_000;

/// Structural caps on a submitted expression tree: nesting depth and
/// leaf-matrix count. Keeps one request from encoding an arbitrarily
/// large job graph (each leaf is also size-capped by [`MAX_SUBMIT_N`],
/// and every planned multiply grid is re-checked against it after the
/// dry-run plan).
const MAX_EXPR_DEPTH: usize = 32;
const MAX_EXPR_LEAVES: usize = 64;

/// Total element budget across ALL leaves of one expression — the same
/// order of memory the non-expression path may allocate (two padded
/// `MAX_SUBMIT_N` operands). Checked **before** each leaf is
/// materialized, so a request full of individually-legal huge leaves is
/// refused instead of OOMing the handler thread.
const MAX_EXPR_ELEMS: usize = 2 * MAX_SUBMIT_N * MAX_SUBMIT_N;

/// Shared server state: the session every job runs through (cluster +
/// leaf backend + Stark knobs + planner) and the job-queue knobs.
pub struct ServerState {
    pub session: StarkSession,
    /// Split selection applied when a request carries no `"b"` field
    /// (`--b`/`--splits` on `stark serve`; `Splits::Auto` = planner).
    pub default_splits: Splits,
    /// Admission bound: maximum queued + running jobs before `submit`
    /// (and the `multiply` sugar) answers with a `busy` rejection.
    pub max_inflight_jobs: usize,
    /// Runner threads executing queued jobs concurrently. Each runs one
    /// job at a time; the engine's fair scheduler interleaves their
    /// stages on the shared worker pool. Clamped to ≥ 1 at start — a
    /// runner-less server would strand every submitted job.
    pub job_runners: usize,
}

/// A parsed, validated request (everything checked at submit time so
/// the runner can't fail on malformed input). `algo`/`splits` may still
/// be auto — resolved by the session's planner at run time (and
/// pre-validated by a dry-run plan at submit time).
struct JobSpec {
    payload: JobPayload,
    return_c: bool,
    /// Optional job deadline: the engine cancels the job cleanly with a
    /// typed `JobTimedOut` once it expires (queued tasks freed, other
    /// jobs unaffected).
    deadline_ms: Option<u64>,
}

enum JobPayload {
    /// One `a @ b_mat` multiply. The operands are session handles built
    /// at parse time — inline payloads, or store-backed `{"ref":"name"}`
    /// handles whose pins ride in the spec: the runner drops the spec
    /// only after the result is published, so a concurrent `drop` of a
    /// referenced name can never invalidate a job in flight.
    Multiply { algo: Algorithm, splits: Splits, a: DistMatrix, b_mat: DistMatrix },
    /// A whole expression DAG, already bound to the server session —
    /// runs as one chained job with a single collect. Store-backed
    /// leaves pin their entries exactly like `Multiply` operands.
    Expr(DistExpr),
}

enum JobStatus {
    Queued,
    Running,
    /// Arc'd so `status`/`wait` can take a handle under the table lock
    /// and deep-copy (or serialize) outside it — a large `return_c`
    /// result must not stall every submit/runner for the clone.
    Done(Arc<Value>),
    Failed(String),
}

impl JobStatus {
    fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
        }
    }
}

struct JobEntry {
    name: String,
    status: JobStatus,
    /// Present while queued; taken by the runner that executes the job.
    spec: Option<JobSpec>,
}

struct Jobs {
    seq: u64,
    entries: BTreeMap<u64, JobEntry>,
    queue: VecDeque<u64>,
    /// Retained finished ids in **completion order** — the eviction
    /// queue. Ordering by completion (not submission id) means a job
    /// that just finished always survives the next `MAX_FINISHED_JOBS`
    /// completions, however early it was submitted.
    finished_order: VecDeque<u64>,
    /// Queued + running count (the admission-control observable).
    inflight: usize,
    /// False once shutdown begins: no further submissions.
    accepting: bool,
}

/// The job table: queue + entries behind one lock, a condvar for both
/// runners (new work) and waiters (completions).
struct JobTable {
    inner: Mutex<Jobs>,
    cv: Condvar,
}

impl JobTable {
    fn new() -> Self {
        Self {
            inner: Mutex::new(Jobs {
                seq: 0,
                entries: BTreeMap::new(),
                queue: VecDeque::new(),
                finished_order: VecDeque::new(),
                inflight: 0,
                accepting: true,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Everything a connection handler or job runner needs.
struct Shared {
    state: ServerState,
    jobs: JobTable,
    shutdown: AtomicBool,
}

/// Tracked connection-handler threads: the stream clone lets `stop()`
/// force-unblock a handler stuck in a read past the drain deadline.
struct ConnSet {
    slots: Mutex<Vec<(TcpStream, std::thread::JoinHandle<()>)>>,
}

/// A running server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    runner_threads: Vec<std::thread::JoinHandle<()>>,
    conns: Arc<ConnSet>,
}

impl Server {
    /// Bind `host:port` (port 0 = ephemeral) and start accepting.
    pub fn start(addr: &str, mut state: ServerState) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        // A server with zero runners would accept jobs that can never
        // run, and one with a zero admission bound would reject every
        // job forever — both knobs degenerate to 1.
        state.job_runners = state.job_runners.max(1);
        state.max_inflight_jobs = state.max_inflight_jobs.max(1);
        let runners = state.job_runners;
        let shared = Arc::new(Shared {
            state,
            jobs: JobTable::new(),
            shutdown: AtomicBool::new(false),
        });
        let conns = Arc::new(ConnSet { slots: Mutex::new(Vec::new()) });

        // If any spawn fails partway, the threads already started must
        // be shut down and joined before the error propagates — an
        // early `?` would leak them parked on the condvar forever.
        let mut runner_threads: Vec<std::thread::JoinHandle<()>> = Vec::with_capacity(runners);
        let abort = |shared: &Arc<Shared>, started: Vec<std::thread::JoinHandle<()>>| {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.jobs.cv.notify_all();
            for t in started {
                let _ = t.join();
            }
        };
        for r in 0..runners {
            let sh = shared.clone();
            match std::thread::Builder::new()
                .name(format!("stark-serve-runner-{r}"))
                .spawn(move || runner_loop(&sh))
            {
                Ok(t) => runner_threads.push(t),
                Err(e) => {
                    abort(&shared, runner_threads);
                    return Err(anyhow::Error::new(e).context("spawning job runner"));
                }
            }
        }

        let sh = shared.clone();
        let cs = conns.clone();
        let accept_result = std::thread::Builder::new()
            .name("stark-serve-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if sh.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            // Reap finished handlers FIRST — independent
                            // of whether this connection can be tracked —
                            // so their sockets and join handles are
                            // released even under fd pressure.
                            {
                                let mut slots = cs.slots.lock().unwrap();
                                let mut live = Vec::with_capacity(slots.len() + 1);
                                for (stream, h) in slots.drain(..) {
                                    if h.is_finished() {
                                        let _ = h.join();
                                    } else {
                                        live.push((stream, h));
                                    }
                                }
                                *slots = live;
                            }
                            // Secure the tracking clone BEFORE spawning:
                            // an untrackable handler would outlive
                            // stop()'s drain (it could neither be
                            // force-closed nor joined), so under fd
                            // pressure the connection is refused instead.
                            let Ok(clone) = s.try_clone() else {
                                continue;
                            };
                            let shared = sh.clone();
                            if let Ok(handle) = std::thread::Builder::new()
                                .name("stark-serve-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(s, &shared);
                                })
                            {
                                cs.slots.lock().unwrap().push((clone, handle));
                            }
                        }
                        // Transient accept failure (EMFILE and friends):
                        // back off and keep serving — exiting here would
                        // silently wedge a server whose runners are still
                        // executing jobs. Shutdown is checked at the top
                        // of every iteration.
                        Err(_) => std::thread::sleep(Duration::from_millis(50)),
                    }
                }
            });
        let accept_thread = match accept_result {
            Ok(t) => t,
            Err(e) => {
                abort(&shared, runner_threads);
                return Err(anyhow::Error::new(e).context("spawning accept thread"));
            }
        };
        Ok(Self {
            addr: local,
            shared,
            accept_thread: Some(accept_thread),
            runner_threads,
            conns,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Shut down in order: stop accepting, drain the job queue (the
    /// running jobs finish, queued ones fail with "shutting down"), then
    /// join every connection handler — giving each [`DRAIN_DEADLINE`] to
    /// finish its in-flight request before its socket is force-closed.
    /// No handler thread is left detached, so shutdown cannot race
    /// handlers writing into freed state.
    pub fn stop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let mut jobs = self.shared.jobs.inner.lock().unwrap();
            jobs.accepting = false;
        }
        self.shared.jobs.cv.notify_all();
        let _ = TcpStream::connect(self.addr); // wake the accept loop
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.runner_threads.drain(..) {
            let _ = t.join();
        }
        // Belt and braces: with the runners gone, fail anything still
        // queued so no waiter sleeps forever on a job that can never run.
        fail_queued(&mut self.shared.jobs.inner.lock().unwrap());
        self.shared.jobs.cv.notify_all();
        drain_connections(&self.conns, Instant::now() + DRAIN_DEADLINE);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Join every tracked handler; past `deadline`, force-close the
/// remaining sockets so blocked reads return and the joins complete.
fn drain_connections(conns: &ConnSet, deadline: Instant) {
    let mut pending: Vec<(TcpStream, std::thread::JoinHandle<()>)> =
        conns.slots.lock().unwrap().drain(..).collect();
    while !pending.is_empty() {
        let mut still = Vec::new();
        for (stream, handle) in pending {
            if handle.is_finished() {
                let _ = handle.join();
            } else if Instant::now() >= deadline {
                let _ = stream.shutdown(Shutdown::Both);
                let _ = handle.join();
            } else {
                still.push((stream, handle));
            }
        }
        pending = still;
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

/// Job-runner thread: pull queued jobs FIFO, execute, publish results.
/// On shutdown, the current job finishes and every still-queued job is
/// failed (a submit got its id back, so the failure is observable).
fn runner_loop(shared: &Shared) {
    loop {
        let (id, spec) = {
            let mut jobs = shared.jobs.inner.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    fail_queued(&mut jobs);
                    shared.jobs.cv.notify_all();
                    return;
                }
                if let Some(id) = jobs.queue.pop_front() {
                    let e = jobs.entries.get_mut(&id).expect("queued job has an entry");
                    let spec = e.spec.take().expect("queued job has a spec");
                    e.status = JobStatus::Running;
                    break (id, spec);
                }
                jobs = shared.jobs.cv.wait(jobs).unwrap();
            }
        };
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute(&shared.state, id, &spec)
        }));
        let mut jobs = shared.jobs.inner.lock().unwrap();
        let status = match outcome {
            Ok(v) => JobStatus::Done(Arc::new(v)),
            Err(panic) => JobStatus::Failed(panic_message(&panic)),
        };
        finish_job(&mut jobs, id, status);
        shared.jobs.cv.notify_all();
    }
}

/// Fail every still-queued job (shutdown paths). Submitters hold the
/// ids, so the failures are observable via `status`/`wait`.
fn fail_queued(jobs: &mut Jobs) {
    while let Some(id) = jobs.queue.pop_front() {
        finish_job(jobs, id, JobStatus::Failed("server shutting down".into()));
    }
}

/// Publish a job's terminal status, release its admission slot, and
/// bound the table: once more than [`MAX_FINISHED_JOBS`] finished
/// entries are retained, the **earliest-finished** one is evicted
/// (completion order, so a just-finished result always survives the
/// next [`MAX_FINISHED_JOBS`] completions regardless of submission
/// order — an actively-waiting client cannot lose a fresh result).
/// Queued/running jobs are never evicted; a waiter that sleeps through
/// the whole retention window gets a loud "unknown job id".
fn finish_job(jobs: &mut Jobs, id: u64, status: JobStatus) {
    finish_job_with(jobs, id, status, MAX_FINISHED_JOBS);
}

/// [`finish_job`] with the retention bound as a parameter, so the
/// `--cfg loom` model can drive the REAL completion path with a small
/// window instead of permuting 64-element queues.
fn finish_job_with(jobs: &mut Jobs, id: u64, status: JobStatus, max: usize) {
    if let Some(e) = jobs.entries.get_mut(&id) {
        e.status = status;
        e.spec = None;
    }
    jobs.inflight = jobs.inflight.saturating_sub(1);
    jobs.finished_order.push_back(id);
    evict_finished(jobs, max);
}

/// Eviction policy, split out with the retention bound as a parameter
/// so the `--cfg loom` model can exhaustively check it with a small
/// window: keep exactly the last `max` finished ids (completion order),
/// drop the entries of everything that rolled off.
fn evict_finished(jobs: &mut Jobs, max: usize) {
    while jobs.finished_order.len() > max {
        if let Some(oldest) = jobs.finished_order.pop_front() {
            jobs.entries.remove(&oldest);
        }
    }
}

fn panic_message(panic: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

/// Run one job end to end through the session and build its result
/// document. The engine job is scoped (`run_job` inside the algorithm),
/// so `out.job` holds only THIS job's stages even with other jobs
/// running concurrently. A typed failure (shapes re-checked, planner)
/// becomes an `ok:false` document rather than a panicking runner.
fn execute(state: &ServerState, id: u64, spec: &JobSpec) -> Value {
    let err_doc = |e: String| {
        Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("job_id", Value::num(id as f64)),
            ("error", Value::str(e)),
        ])
    };
    let mut fields = vec![("ok", Value::Bool(true)), ("job_id", Value::num(id as f64))];
    let (c, job, leaf_calls, leaf_ms) = match &spec.payload {
        JobPayload::Multiply { algo, splits, a, b_mat } => {
            let mut builder = a.multiply(b_mat).algorithm(*algo).splits(*splits);
            if let Some(ms) = spec.deadline_ms {
                builder = builder.deadline(ms);
            }
            let out = match builder.collect() {
                Ok(out) => out,
                Err(e) => return err_doc(e.to_string()),
            };
            fields.push(("algo", Value::str(algo.to_string())));
            // What the planner/session actually ran (= "algo" unless auto).
            fields.push(("algorithm", Value::str(out.plan.algorithm.to_string())));
            fields.push(("b", Value::num(out.plan.b as f64)));
            (out.c, out.job, out.leaf_calls, out.leaf_ms)
        }
        JobPayload::Expr(expr) => {
            let out = match expr.collect_with(spec.deadline_ms) {
                Ok(out) => out,
                Err(e) => return err_doc(e.to_string()),
            };
            fields.push(("algo", Value::str("expr")));
            fields.push(("expression", Value::str(out.plan.expression.clone())));
            fields.push(("reordered", Value::Bool(out.plan.reordered)));
            fields.push((
                "multiplies",
                Value::Array(
                    out.plan
                        .multiplies
                        .iter()
                        .map(|np| {
                            Value::obj(vec![
                                ("label", Value::str(np.label.clone())),
                                ("algorithm", Value::str(np.plan.algorithm.to_string())),
                                ("b", Value::num(np.plan.b as f64)),
                                ("n", Value::num(np.plan.n as f64)),
                                ("fused", Value::Bool(np.fused)),
                            ])
                        })
                        .collect(),
                ),
            ));
            fields.push((
                "inversions",
                Value::Array(
                    out.plan
                        .inversions
                        .iter()
                        .map(|np| {
                            Value::obj(vec![
                                ("label", Value::str(np.label.clone())),
                                ("n", Value::num(np.plan.n as f64)),
                                ("leaf", Value::num(np.plan.leaf as f64)),
                                ("depth", Value::num(np.plan.depth() as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
            let collects =
                out.job.stages.iter().filter(|s| s.label == "result/collect").count();
            fields.push(("collects", Value::num(collects as f64)));
            (out.c, out.job, out.leaf_calls, out.leaf_ms)
        }
    };
    fields.extend([
        ("rows", Value::num(c.rows() as f64)),
        ("cols", Value::num(c.cols() as f64)),
        ("wall_ms", Value::num(job.wall_ms)),
        ("leaf_calls", Value::num(leaf_calls as f64)),
        ("leaf_ms", Value::num(leaf_ms)),
        ("frobenius", Value::num(c.frobenius())),
        ("shuffle_bytes", Value::num(job.total_shuffle_bytes() as f64)),
        // Fault-tolerance counters (DESIGN.md S20): all zero on a clean
        // chaos-free run except attempts == tasks.
        ("tasks", Value::num(job.total_tasks() as f64)),
        ("attempts", Value::num(job.total_attempts() as f64)),
        ("recomputed_partitions", Value::num(job.total_recomputed_partitions() as f64)),
        ("speculative_wins", Value::num(job.total_speculative_wins() as f64)),
        // Exactly this job's stage metrics (count = eq. (25) for Stark).
        ("stages", Value::Array(job.stages.iter().map(|s| s.to_json()).collect())),
        // Store counters so a client can watch hit/miss/eviction/spill
        // behavior of `{"ref":...}` operands without a separate `ls`.
        ("store", state.session.store_metrics().to_value()),
    ]);
    if spec.return_c {
        fields.push(("c", matrix_to_json(&c)));
    }
    Value::obj(fields)
}

fn parse_matrix(v: &Value) -> Result<DenseMatrix> {
    let rows = v.as_array().context("matrix must be an array of rows")?;
    anyhow::ensure!(!rows.is_empty(), "empty matrix");
    let mut data = Vec::new();
    let cols = rows[0].as_array().context("row must be an array")?.len();
    for row in rows {
        let row = row.as_array().context("row must be an array")?;
        anyhow::ensure!(row.len() == cols, "ragged matrix");
        for x in row {
            data.push(x.as_f64().context("matrix element must be a number")?);
        }
    }
    Ok(DenseMatrix::from_vec(rows.len(), cols, data))
}

fn matrix_to_json(m: &DenseMatrix) -> Value {
    Value::Array(
        (0..m.rows())
            .map(|r| Value::Array((0..m.cols()).map(|c| Value::num(m.get(r, c))).collect()))
            .collect(),
    )
}

/// Parse a request's `"b"` field: a number, `"auto"`, or absent.
fn parse_splits(req: &Value, default: Splits) -> Result<Splits> {
    match req.get("b") {
        None => Ok(default),
        Some(Value::String(s)) => s.parse::<Splits>().map_err(anyhow::Error::msg),
        Some(v) => {
            Ok(Splits::Fixed(v.as_usize().context("\"b\" must be a number or \"auto\"")?))
        }
    }
}

/// Per-expression leaf budget: how many leaves and how many total
/// elements one request may materialize (charged *before* allocating).
struct LeafBudget {
    leaves: usize,
    elems: usize,
}

impl LeafBudget {
    fn new() -> Self {
        Self { leaves: 0, elems: 0 }
    }

    /// Charge one `rows × cols` leaf against the budget.
    fn charge(&mut self, rows: usize, cols: usize) -> Result<()> {
        self.leaves += 1;
        anyhow::ensure!(self.leaves <= MAX_EXPR_LEAVES, "more than {MAX_EXPR_LEAVES} leaves");
        self.elems = self.elems.saturating_add(rows.saturating_mul(cols));
        anyhow::ensure!(
            self.elems <= MAX_EXPR_ELEMS,
            "expression leaves total more than {MAX_EXPR_ELEMS} elements"
        );
        Ok(())
    }
}

/// Parse one node of a submitted expression tree (see the module docs
/// for the grammar). Depth is capped at [`MAX_EXPR_DEPTH`]; leaves are
/// charged against a count **and** total-element budget before any
/// payload is materialized.
fn parse_expr(
    session: &StarkSession,
    v: &Value,
    depth: usize,
    budget: &mut LeafBudget,
) -> Result<DistExpr> {
    anyhow::ensure!(depth <= MAX_EXPR_DEPTH, "expression nests deeper than {MAX_EXPR_DEPTH}");
    let args = |key: &str, want: usize| -> Result<Vec<Value>> {
        let arr: Vec<Value> =
            v.get(key).and_then(Value::as_array).map(|a| a.to_vec()).unwrap_or_default();
        anyhow::ensure!(arr.len() == want, "\"{key}\" takes exactly {want} operands");
        Ok(arr)
    };
    if let Some(m) = v.get("matrix") {
        // Shape-check the JSON before building the payload.
        let rows = m.as_array().map(<[Value]>::len).unwrap_or(0);
        let cols = m
            .as_array()
            .and_then(|r| r.first())
            .and_then(Value::as_array)
            .map(<[Value]>::len)
            .unwrap_or(0);
        anyhow::ensure!(
            rows >= 1 && rows <= MAX_SUBMIT_N && cols <= MAX_SUBMIT_N,
            "matrix leaf must be non-empty with at most {MAX_SUBMIT_N} rows/cols"
        );
        budget.charge(rows, cols)?;
        let m = parse_matrix(m)?;
        return Ok(session.matrix_arc(Arc::new(m)).expr());
    }
    if let Some(g) = v.get("gen") {
        let n = g.get("n").and_then(Value::as_usize).context("\"gen\" needs \"n\"")?;
        anyhow::ensure!(n >= 1 && n <= MAX_SUBMIT_N, "\"gen\" n must be in 1..={MAX_SUBMIT_N}");
        budget.charge(n, n)?;
        let seed = g.get("seed").and_then(Value::as_u64).unwrap_or(42);
        return Ok(session.matrix_arc(Arc::new(DenseMatrix::random(n, n, seed))).expr());
    }
    if let Some(r) = v.get("ref") {
        // Store-backed leaf: the handle pins the entry, so the name can
        // be dropped mid-job without invalidating this expression. The
        // A010 dry-run in parse_spec already vouched the name exists —
        // this lookup can still lose a race to a concurrent drop, which
        // surfaces as the same typed error.
        let name = r.as_str().context("\"ref\" must be a string matrix name")?;
        let h = session.get(name).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        budget.charge(h.rows(), h.cols())?;
        return Ok(h.expr());
    }
    if v.get("mul").is_some() {
        let ops = args("mul", 2)?;
        let l = parse_expr(session, &ops[0], depth + 1, budget)?;
        let r = parse_expr(session, &ops[1], depth + 1, budget)?;
        // Node-level pinning rides on the same object: {"mul":[..],
        // "algo":"stark","b":4}.
        let algo: Algorithm = v
            .get("algo")
            .and_then(Value::as_str)
            .unwrap_or("auto")
            .parse()
            .map_err(anyhow::Error::msg)?;
        let splits = parse_splits(v, Splits::Auto)?;
        return Ok(l.multiply_with(&r, algo, splits));
    }
    if v.get("add").is_some() || v.get("sub").is_some() {
        let (key, sign) = if v.get("add").is_some() { ("add", 1.0) } else { ("sub", -1.0) };
        let arr: Vec<Value> =
            v.get(key).and_then(Value::as_array).map(|a| a.to_vec()).unwrap_or_default();
        anyhow::ensure!(arr.len() >= 2, "\"{key}\" takes at least two operands");
        let mut acc = parse_expr(session, &arr[0], depth + 1, budget)?;
        for op in &arr[1..] {
            let rhs = parse_expr(session, op, depth + 1, budget)?;
            acc = if sign > 0.0 { acc.add(&rhs) } else { acc.sub(&rhs) };
        }
        return Ok(acc);
    }
    if v.get("scale").is_some() {
        let ops = args("scale", 2)?;
        let s = ops[0].as_f64().context("\"scale\" takes [number, node]")?;
        anyhow::ensure!(s.is_finite(), "\"scale\" factor must be finite");
        return Ok(parse_expr(session, &ops[1], depth + 1, budget)?.scale(s));
    }
    if let Some(inner) = v.get("t").or_else(|| v.get("transpose")) {
        return Ok(parse_expr(session, inner, depth + 1, budget)?.transpose());
    }
    if let Some(inner) = v.get("inv").or_else(|| v.get("inverse")) {
        return Ok(parse_expr(session, inner, depth + 1, budget)?.inverse());
    }
    if v.get("solve").is_some() {
        let ops = args("solve", 2)?;
        let a = parse_expr(session, &ops[0], depth + 1, budget)?;
        let rhs = parse_expr(session, &ops[1], depth + 1, budget)?;
        return Ok(a.solve(&rhs));
    }
    if v.get("pow").is_some() {
        let ops = args("pow", 2)?;
        // Signed: k < 0 inverts first (x^-k = (x⁻¹)^k). The util JSON
        // layer has no integer accessor, so integrality is checked on
        // the f64 (a NaN/∞ fract() is NaN, failing the check too).
        let kf = ops[1].as_f64().context("\"pow\" takes [node, k]")?;
        anyhow::ensure!(
            kf.fract() == 0.0 && kf.abs() <= 64.0,
            "\"pow\" k must be an integer in -64..=64"
        );
        let k = kf as i32;
        anyhow::ensure!(k != 0, "\"pow\" k must be nonzero (k=0 is not supported)");
        return Ok(parse_expr(session, &ops[0], depth + 1, budget)?.pow(k));
    }
    anyhow::bail!(
        "unknown expression node (want one of matrix/gen/ref/mul/add/sub/scale/t/inv/solve/pow): {}",
        v.to_json()
    )
}

/// Parse the serve protocol's expression-tree JSON into a [`DistExpr`]
/// against `session`, under the same leaf/depth budgets a submitted
/// request gets — the `stark analyze` CLI shares serve's grammar.
pub fn expr_from_json(session: &StarkSession, tree: &Value) -> Result<DistExpr> {
    let mut budget = LeafBudget::new();
    parse_expr(session, tree, 0, &mut budget)
}

/// Parse and validate a submit/multiply request into a [`JobSpec`] —
/// every invariant the session checks at run time is dry-run here (a
/// planner resolution or expression plan), so malformed requests are
/// rejected at submit time instead of failing the job.
fn parse_spec(session: &StarkSession, req: &Value, default_splits: Splits) -> Result<JobSpec> {
    let return_c = req.get("return_c").and_then(Value::as_bool).unwrap_or(false);
    let deadline_ms = req.get("deadline_ms").and_then(Value::as_u64);
    if let Some(tree) = req.get("expr") {
        // Dangling `{"ref":...}` dry-run (STARK-A010): every referenced
        // name must be in the store NOW, before any leaf materializes.
        // Unconditional — a dangling ref is an error in every build; the
        // diagnostic beats the raw lookup failure parse_expr would hit.
        let store = session.store().clone();
        let diags = crate::analyze::analyze_expr_refs(tree, &|name| store.contains(name));
        anyhow::ensure!(
            !crate::analyze::has_errors(&diags),
            "expression rejected by static analysis:\n{}",
            crate::analyze::render(&diags)
        );
        let mut budget = LeafBudget::new();
        let expr = parse_expr(session, tree, 0, &mut budget)?;
        // Dry-run the whole chain plan: shape/session/split errors and
        // every node's padded grid surface now, not in the runner.
        let plan = expr.plan().map_err(|e| anyhow::anyhow!(e.to_string()))?;
        // Static dry-run (DESIGN.md S19): reject malformed plans at
        // submit time, before the runner allocates anything.
        if cfg!(debug_assertions) || session.stark_config().strict_analyze {
            let diags = crate::analyze::analyze_plan(&plan);
            anyhow::ensure!(
                !crate::analyze::has_errors(&diags),
                "plan rejected by static analysis:\n{}",
                crate::analyze::render(&diags)
            );
        }
        for np in &plan.multiplies {
            anyhow::ensure!(
                np.plan.n <= MAX_SUBMIT_N,
                "expression node {} plans a padded grid {} beyond the server cap {MAX_SUBMIT_N}",
                np.label,
                np.plan.n
            );
        }
        for np in &plan.inversions {
            anyhow::ensure!(
                np.plan.n <= MAX_SUBMIT_N,
                "inversion node {} plans a padded grid {} beyond the server cap {MAX_SUBMIT_N}",
                np.label,
                np.plan.n
            );
        }
        return Ok(JobSpec { payload: JobPayload::Expr(expr), return_c, deadline_ms });
    }
    let algo: Algorithm = req
        .get("algo")
        .and_then(Value::as_str)
        .unwrap_or("stark")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let splits = parse_splits(req, default_splits)?;
    let (a, b_mat) = match (req.get("a"), req.get("b_mat")) {
        (Some(a), Some(bm)) => (parse_operand(session, a)?, parse_operand(session, bm)?),
        _ => {
            let n = req
                .get("n")
                .and_then(Value::as_usize)
                .context("provide either inline \"a\"/\"b_mat\" or a size \"n\"")?;
            // Checked BEFORE generation — the allocation is n²·8 bytes.
            anyhow::ensure!(
                n >= 1 && n <= MAX_SUBMIT_N,
                "\"n\" must be in 1..={MAX_SUBMIT_N}, got {n}"
            );
            let seed = req.get("seed").and_then(Value::as_u64).unwrap_or(42);
            (
                session.matrix_arc(Arc::new(DenseMatrix::random(n, n, seed))),
                session.matrix_arc(Arc::new(DenseMatrix::random(n, n, seed + 1))),
            )
        }
    };
    anyhow::ensure!(
        a.cols() == b_mat.rows(),
        "contraction mismatch: a is {}x{}, b_mat is {}x{}",
        a.rows(),
        a.cols(),
        b_mat.rows(),
        b_mat.cols()
    );
    // Dry-run the planner: rejects invalid (algorithm, b) combinations
    // (e.g. stark with a non-power-of-two b) with the typed message and
    // yields the padded working size the job will actually allocate.
    let max_dim = a.rows().max(a.cols()).max(b_mat.cols());
    let plan = session.plan_for(algo, splits, max_dim).map_err(anyhow::Error::msg)?;
    // Bound the padded working size (pad-and-crop squares the largest
    // dimension): one oversized request must not OOM the whole server.
    anyhow::ensure!(
        plan.n <= MAX_SUBMIT_N,
        "workload too large: padded size {} exceeds the server cap {MAX_SUBMIT_N}",
        plan.n
    );
    if cfg!(debug_assertions) || session.stark_config().strict_analyze {
        let diags = crate::analyze::analyze_node_plan("", &plan);
        anyhow::ensure!(
            !crate::analyze::has_errors(&diags),
            "plan rejected by static analysis:\n{}",
            crate::analyze::render(&diags)
        );
    }
    Ok(JobSpec { payload: JobPayload::Multiply { algo, splits, a, b_mat }, return_c, deadline_ms })
}

/// Parse one `multiply`/`submit` operand: an inline `[[...]]` payload,
/// or `{"ref":"name"}` resolving through the session store (the handle
/// pins the entry for the job's whole lifetime).
fn parse_operand(session: &StarkSession, v: &Value) -> Result<DistMatrix> {
    if let Some(r) = v.get("ref") {
        let name = r.as_str().context("\"ref\" must be a string matrix name")?;
        return session.get(name).map_err(|e| anyhow::anyhow!(e.to_string()));
    }
    let m = parse_matrix(v)?;
    anyhow::ensure!(
        m.rows() <= MAX_SUBMIT_N && m.cols() <= MAX_SUBMIT_N,
        "operand must be at most {MAX_SUBMIT_N} rows/cols"
    );
    Ok(session.matrix_arc(Arc::new(m)))
}

/// Render a [`Plan`] as the `plan` op's response document.
fn plan_to_json(plan: &Plan) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("algorithm", Value::str(plan.algorithm.to_string())),
        ("b", Value::num(plan.b as f64)),
        ("n", Value::num(plan.n as f64)),
        ("predicted_wall_ms", Value::num(plan.predicted_wall_ms())),
        (
            "stages",
            Value::Array(
                plan.predicted
                    .stages
                    .iter()
                    .map(|st| {
                        Value::obj(vec![
                            ("label", Value::str(st.label.clone())),
                            ("comp", Value::num(st.comp)),
                            ("comm", Value::num(st.comm)),
                            ("pf", Value::num(st.pf)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "considered",
            Value::Array(
                plan.considered
                    .iter()
                    .map(|c| {
                        Value::obj(vec![
                            ("algorithm", Value::str(c.algorithm.to_string())),
                            ("b", Value::num(c.b as f64)),
                            ("wall_ms", Value::num(c.wall_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

enum Submitted {
    Accepted(u64),
    Rejected(Value),
}

/// Admission-controlled enqueue. Returns the job id or the rejection
/// document (`busy` when the queue is at its bound, an error once
/// shutdown began).
fn submit_job(shared: &Shared, spec: JobSpec) -> Submitted {
    let name = match &spec.payload {
        JobPayload::Multiply { algo, splits, a, .. } => {
            format!("{} n={} b={}", algo, a.rows(), splits)
        }
        JobPayload::Expr(expr) => format!("expr {}x{}", expr.rows(), expr.cols()),
    };
    let mut jobs = shared.jobs.inner.lock().unwrap();
    if !jobs.accepting || shared.shutdown.load(Ordering::SeqCst) {
        return Submitted::Rejected(Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", Value::str("server shutting down")),
        ]));
    }
    if jobs.inflight >= shared.state.max_inflight_jobs {
        return Submitted::Rejected(Value::obj(vec![
            ("ok", Value::Bool(false)),
            ("busy", Value::Bool(true)),
            (
                "error",
                Value::str(format!(
                    "server busy: {} jobs in flight (max {})",
                    jobs.inflight, shared.state.max_inflight_jobs
                )),
            ),
        ]));
    }
    jobs.seq += 1;
    let id = jobs.seq;
    jobs.entries.insert(id, JobEntry { name, status: JobStatus::Queued, spec: Some(spec) });
    jobs.queue.push_back(id);
    jobs.inflight += 1;
    drop(jobs);
    shared.jobs.cv.notify_all();
    Submitted::Accepted(id)
}

/// Typed rejection for a `status`/`wait` naming a job id this server
/// never assigned (or one that rolled off the finished-job window):
/// `{"ok":false,"unknown_job":true}` so clients can branch without
/// string-matching the error text.
fn unknown_job_doc(id: u64) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("job_id", Value::num(id as f64)),
        ("unknown_job", Value::Bool(true)),
        ("error", Value::str(StarkError::UnknownJob { job_id: id }.to_string())),
    ])
}

/// Block until job `id` completes (or `timeout` elapses) and return its
/// result document. The result's deep copy happens after the table
/// lock is released — only the `Arc` handle is taken under it.
fn wait_for(shared: &Shared, id: u64, timeout: Option<Duration>) -> Result<Value> {
    let deadline = timeout.map(|t| Instant::now() + t);
    let done: Arc<Value> = {
        let mut jobs = shared.jobs.inner.lock().unwrap();
        loop {
            match jobs.entries.get(&id) {
                None => return Ok(unknown_job_doc(id)),
                Some(e) => match &e.status {
                    JobStatus::Done(v) => break v.clone(),
                    JobStatus::Failed(msg) => {
                        return Ok(Value::obj(vec![
                            ("ok", Value::Bool(false)),
                            ("job_id", Value::num(id as f64)),
                            ("error", Value::str(msg.clone())),
                        ]))
                    }
                    JobStatus::Queued | JobStatus::Running => {}
                },
            }
            jobs = match deadline {
                None => shared.jobs.cv.wait(jobs).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(Value::obj(vec![
                            ("ok", Value::Bool(false)),
                            ("job_id", Value::num(id as f64)),
                            ("timeout", Value::Bool(true)),
                            ("error", Value::str("wait timed out")),
                        ]));
                    }
                    shared.jobs.cv.wait_timeout(jobs, d - now).unwrap().0
                }
            };
        }
    };
    Ok((*done).clone())
}

fn handle_connection(stream: TcpStream, shared: &Shared) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(&line, shared) {
            Ok(v) => v,
            Err(e) => Value::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", Value::str(format!("{e:#}"))),
            ]),
        };
        writer.write_all(response.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Handle one request line, producing the response document.
fn handle_request(line: &str, shared: &Shared) -> Result<Value> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
    let op = req.get("op").and_then(Value::as_str).context("missing \"op\"")?;
    let job_id_of = |req: &Value| -> Result<u64> {
        req.get("job_id").and_then(Value::as_u64).context("missing \"job_id\"")
    };
    match op {
        "ping" => {
            let inflight = shared.jobs.inner.lock().unwrap().inflight;
            Ok(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("service", Value::str("stark")),
                ("version", Value::str(env!("CARGO_PKG_VERSION"))),
                ("backend", Value::str(shared.state.session.backend().name())),
                ("jobs_inflight", Value::num(inflight as f64)),
            ]))
        }
        "shutdown" => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.jobs.inner.lock().unwrap().accepting = false;
            shared.jobs.cv.notify_all();
            Ok(Value::obj(vec![("ok", Value::Bool(true)), ("stopping", Value::Bool(true))]))
        }
        "submit" => {
            let spec = parse_spec(&shared.state.session, &req, shared.state.default_splits)?;
            match submit_job(shared, spec) {
                Submitted::Accepted(id) => Ok(Value::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("job_id", Value::num(id as f64)),
                    ("status", Value::str("queued")),
                ])),
                Submitted::Rejected(doc) => Ok(doc),
            }
        }
        "status" => {
            let id = job_id_of(&req)?;
            // Take cheap handles under the lock; deep-copy the result
            // document only after releasing it.
            let (name, status, result, error) = {
                let jobs = shared.jobs.inner.lock().unwrap();
                let Some(e) = jobs.entries.get(&id) else {
                    return Ok(unknown_job_doc(id));
                };
                let result = match &e.status {
                    JobStatus::Done(v) => Some(v.clone()),
                    _ => None,
                };
                let error = match &e.status {
                    JobStatus::Failed(msg) => Some(msg.clone()),
                    _ => None,
                };
                (e.name.clone(), e.status.as_str(), result, error)
            };
            let mut fields = vec![
                ("ok", Value::Bool(true)),
                ("job_id", Value::num(id as f64)),
                ("name", Value::str(name)),
                ("status", Value::str(status)),
            ];
            if let Some(v) = result {
                // Surface the fault counters at the top level too, so a
                // poller sees recovery activity without digging into the
                // full result document.
                for k in ["tasks", "attempts", "recomputed_partitions", "speculative_wins"] {
                    if let Some(x) = v.get(k) {
                        fields.push((k, x.clone()));
                    }
                }
                fields.push(("result", (*v).clone()));
            }
            if let Some(msg) = error {
                fields.push(("error", Value::str(msg)));
            }
            Ok(Value::obj(fields))
        }
        "wait" => {
            let id = job_id_of(&req)?;
            let timeout = req
                .get("timeout_ms")
                .and_then(Value::as_u64)
                .map(|ms| Duration::from_millis(ms.min(MAX_WAIT_TIMEOUT_MS)));
            wait_for(shared, id, timeout)
        }
        "jobs" => {
            let jobs = shared.jobs.inner.lock().unwrap();
            let mut failed_jobs = 0usize;
            let list: Vec<Value> = jobs
                .entries
                .iter()
                .map(|(id, e)| {
                    let mut fields = vec![
                        ("job_id", Value::num(*id as f64)),
                        ("name", Value::str(e.name.clone())),
                        ("status", Value::str(e.status.as_str())),
                    ];
                    // Per-job failure/recovery counters (DESIGN.md S20).
                    let failed = match &e.status {
                        JobStatus::Failed(_) => true,
                        JobStatus::Done(v) => {
                            for k in
                                ["tasks", "attempts", "recomputed_partitions", "speculative_wins"]
                            {
                                if let Some(x) = v.get(k) {
                                    fields.push((k, x.clone()));
                                }
                            }
                            v.get("ok") == Some(&Value::Bool(false))
                        }
                        _ => false,
                    };
                    if failed {
                        failed_jobs += 1;
                        fields.push(("failed", Value::Bool(true)));
                    }
                    Value::obj(fields)
                })
                .collect();
            Ok(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("inflight", Value::num(jobs.inflight as f64)),
                ("failed_jobs", Value::num(failed_jobs as f64)),
                ("jobs", Value::Array(list)),
            ]))
        }
        // The planner as a service: "what would you run?" without
        // running it. "algo"/"b" default to auto here (unlike submit,
        // where they default to stark/the server's --b) — asking for a
        // plan implies wanting the planner's opinion.
        "plan" => {
            let n = req.get("n").and_then(Value::as_usize).context("missing \"n\"")?;
            anyhow::ensure!(n >= 1 && n <= MAX_SUBMIT_N, "\"n\" must be in 1..={MAX_SUBMIT_N}");
            let algo: Algorithm = req
                .get("algo")
                .and_then(Value::as_str)
                .unwrap_or("auto")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let splits = parse_splits(&req, Splits::Auto)?;
            let plan =
                shared.state.session.plan_for(algo, splits, n).map_err(anyhow::Error::msg)?;
            Ok(plan_to_json(&plan))
        }
        // Synchronous multiply: submit + wait, same admission control.
        "multiply" => {
            let spec = parse_spec(&shared.state.session, &req, shared.state.default_splits)?;
            match submit_job(shared, spec) {
                Submitted::Accepted(id) => wait_for(shared, id, None),
                Submitted::Rejected(doc) => Ok(doc),
            }
        }
        // ---- named-matrix store (module docs, NAMED MATRICES) ----
        "put" => {
            let session = &shared.state.session;
            let name = req
                .get("name")
                .and_then(Value::as_str)
                .context("\"put\" needs a string \"name\"")?;
            let data = if let Some(m) = req.get("matrix") {
                let m = parse_matrix(m)?;
                anyhow::ensure!(
                    m.rows() <= MAX_SUBMIT_N && m.cols() <= MAX_SUBMIT_N,
                    "\"put\" payload must be at most {MAX_SUBMIT_N} rows/cols"
                );
                Arc::new(m)
            } else if let Some(g) = req.get("gen") {
                let n = g.get("n").and_then(Value::as_usize).context("\"gen\" needs \"n\"")?;
                anyhow::ensure!(
                    n >= 1 && n <= MAX_SUBMIT_N,
                    "\"gen\" n must be in 1..={MAX_SUBMIT_N}"
                );
                let seed = g.get("seed").and_then(Value::as_u64).unwrap_or(42);
                Arc::new(DenseMatrix::random(n, n, seed))
            } else {
                anyhow::bail!("\"put\" needs a \"matrix\" payload or a \"gen\" generator")
            };
            let out = session.put(name, data).map_err(|e| anyhow::anyhow!(e.to_string()))?;
            Ok(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("name", Value::str(name)),
                ("rows", Value::num(out.rows as f64)),
                ("cols", Value::num(out.cols as f64)),
                ("bytes", Value::num(out.bytes as f64)),
                ("deduped", Value::Bool(out.deduped)),
                ("replaced", Value::Bool(out.replaced)),
                ("store", session.store_metrics().to_value()),
            ]))
        }
        "get" => {
            let session = &shared.state.session;
            let name = req
                .get("name")
                .and_then(Value::as_str)
                .context("\"get\" needs a string \"name\"")?;
            let want_values = req.get("values").and_then(Value::as_bool).unwrap_or(false);
            // Metadata comes from the listing (no reload of a spilled
            // payload); only "values":true pulls the payload back in.
            let Some(info) = session.store().list().into_iter().find(|e| e.name == name) else {
                return Ok(unknown_name_doc(name));
            };
            let mut fields = vec![
                ("ok", Value::Bool(true)),
                ("name", Value::str(name)),
                ("rows", Value::num(info.rows as f64)),
                ("cols", Value::num(info.cols as f64)),
                ("bytes", Value::num(info.payload_bytes as f64)),
                ("resident", Value::Bool(info.resident)),
                ("pins", Value::num(info.pins as f64)),
                ("splits_computed", Value::num(info.splits_computed as f64)),
                ("hash", Value::str(format!("{:016x}", info.hash))),
            ];
            if want_values {
                match session.get(name) {
                    Ok(h) => fields.push(("values", matrix_to_json(h.dense()))),
                    // Lost a race to a concurrent drop between list()
                    // and get(): same typed rejection as never-bound.
                    Err(_) => return Ok(unknown_name_doc(name)),
                }
            }
            fields.push(("store", session.store_metrics().to_value()));
            Ok(Value::obj(fields))
        }
        "drop" => {
            let session = &shared.state.session;
            let name = req
                .get("name")
                .and_then(Value::as_str)
                .context("\"drop\" needs a string \"name\"")?;
            match session.drop_matrix(name) {
                Ok(out) => Ok(Value::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("name", Value::str(name)),
                    ("dropped", Value::Bool(out == DropOutcome::Dropped)),
                    // The name is unbound either way; pinned means the
                    // entry itself lives until the last in-flight job
                    // holding it finishes.
                    ("pinned", Value::Bool(out == DropOutcome::Pinned)),
                    ("store", session.store_metrics().to_value()),
                ])),
                Err(StarkError::UnknownName { .. }) => Ok(unknown_name_doc(name)),
                Err(e) => anyhow::bail!(e.to_string()),
            }
        }
        "ls" => {
            let session = &shared.state.session;
            let entries: Vec<Value> = session
                .store()
                .list()
                .into_iter()
                .map(|e| {
                    Value::obj(vec![
                        ("name", Value::str(e.name)),
                        ("rows", Value::num(e.rows as f64)),
                        ("cols", Value::num(e.cols as f64)),
                        ("bytes", Value::num(e.payload_bytes as f64)),
                        ("splits_bytes", Value::num(e.splits_bytes as f64)),
                        ("resident", Value::Bool(e.resident)),
                        ("pins", Value::num(e.pins as f64)),
                        ("splits_computed", Value::num(e.splits_computed as f64)),
                        ("hash", Value::str(format!("{:016x}", e.hash))),
                    ])
                })
                .collect();
            Ok(Value::obj(vec![
                ("ok", Value::Bool(true)),
                ("entries", Value::Array(entries)),
                ("store", session.store_metrics().to_value()),
            ]))
        }
        other => anyhow::bail!("unknown op {other:?}"),
    }
}

/// Typed rejection mirroring [`unknown_job_doc`] for store lookups:
/// `{"ok":false,"unknown_name":true}` when `name` is not bound (never
/// put, or dropped).
fn unknown_name_doc(name: &str) -> Value {
    Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("name", Value::str(name)),
        ("unknown_name", Value::Bool(true)),
        ("error", Value::str(StarkError::UnknownName { name: name.to_string() }.to_string())),
    ])
}

/// Simple blocking client: send one request line, read one response.
pub fn request(addr: &str, body: &Value) -> Result<Value> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(body.to_json().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

/// Concurrency model for the job table's completion-order eviction,
/// compiled only under `RUSTFLAGS="--cfg loom" cargo test`. `Jobs` is
/// only ever mutated inside [`JobTable::state`]'s single mutex, so any
/// real execution of racing runner threads equals SOME sequential merge
/// of their `finish_job` critical sections — enumerating every merge of
/// the per-thread completion sequences is therefore an exhaustive
/// interleaving model for this lock discipline (see the matching module
/// in `engine/cluster.rs` for the full argument).
#[cfg(all(test, loom))]
mod loom_model {
    use super::*;

    fn table_with_running(ids: &[u64]) -> Jobs {
        let mut jobs = Jobs {
            seq: 0,
            entries: BTreeMap::new(),
            queue: VecDeque::new(),
            finished_order: VecDeque::new(),
            inflight: ids.len(),
            accepting: true,
        };
        for &id in ids {
            jobs.entries.insert(
                id,
                JobEntry { name: format!("j{id}"), status: JobStatus::Running, spec: None },
            );
        }
        jobs
    }

    /// Retained finished entries must be EXACTLY the last `max` ids in
    /// completion order, whatever order racing runners finish jobs in.
    fn assert_eviction_invariant(jobs: &Jobs, completed: &[u64], max: usize) {
        let expect: Vec<u64> = completed[completed.len().saturating_sub(max)..].to_vec();
        let got: Vec<u64> = jobs.finished_order.iter().copied().collect();
        assert_eq!(got, expect, "retention window diverged from completion order");
        for id in completed {
            assert_eq!(
                jobs.entries.contains_key(id),
                expect.contains(id),
                "entry {id} retention disagrees with the completion-order window"
            );
        }
        assert_eq!(jobs.inflight, 0, "every completion must release one admission slot");
    }

    #[test]
    fn eviction_keeps_last_max_under_all_completion_interleavings() {
        // Two runner threads each own three jobs and finish them in
        // program order; every merge of the two sequences is a distinct
        // global completion order. Window max=2 forces eviction on all
        // but the first two completions of every merge.
        let thread_a = [1u64, 2, 3];
        let thread_b = [10u64, 20, 30];
        let max = 2usize;
        let mut count = 0usize;
        fn recurse(a: &[u64], b: &[u64], order: &mut Vec<u64>, max: usize, count: &mut usize) {
            if a.is_empty() && b.is_empty() {
                *count += 1;
                let all: Vec<u64> = order.clone();
                let mut jobs = table_with_running(&all);
                for &id in order.iter() {
                    finish_job_with(&mut jobs, id, JobStatus::Done, max);
                }
                assert_eviction_invariant(&jobs, &all, max);
                return;
            }
            if let Some((&first, rest)) = a.split_first() {
                order.push(first);
                recurse(rest, b, order, max, count);
                order.pop();
            }
            if let Some((&first, rest)) = b.split_first() {
                order.push(first);
                recurse(a, rest, order, max, count);
                order.pop();
            }
        }
        recurse(&thread_a, &thread_b, &mut Vec::new(), max, &mut count);
        // C(6,3) = 20 merges of two 3-job runners.
        assert_eq!(count, 20, "interleaving enumeration is not exhaustive");
    }

    /// Queued (never-finished) jobs must survive any amount of churn.
    #[test]
    fn queued_jobs_survive_eviction_in_every_interleaving() {
        for max in 1..=3usize {
            let mut jobs = table_with_running(&[99]);
            jobs.queue.push_back(99);
            for id in 1..=8u64 {
                jobs.entries.insert(
                    id,
                    JobEntry { name: format!("j{id}"), status: JobStatus::Running, spec: None },
                );
                finish_job_with(&mut jobs, id, JobStatus::Done, max);
                assert!(
                    jobs.entries.contains_key(&99),
                    "queued job evicted at max={max} after {id} completions"
                );
                assert!(jobs.finished_order.len() <= max);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::engine::ClusterConfig;

    fn test_state() -> ServerState {
        let session = StarkSession::builder()
            .cluster(ClusterConfig::new(2, 1))
            .backend_kind(BackendKind::Packed)
            .build()
            .unwrap();
        ServerState {
            session,
            default_splits: Splits::Fixed(2),
            max_inflight_jobs: 8,
            job_runners: 2,
        }
    }

    fn test_server() -> Server {
        Server::start("127.0.0.1:0", test_state()).unwrap()
    }

    fn req(addr: &str, pairs: Vec<(&str, Value)>) -> Value {
        request(addr, &Value::obj(pairs)).unwrap()
    }

    #[test]
    fn ping_roundtrip() {
        let server = test_server();
        let resp = req(&server.addr().to_string(), vec![("op", Value::str("ping"))]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(resp.get("service").unwrap().as_str(), Some("stark"));
        assert_eq!(resp.get("jobs_inflight").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn multiply_by_seed() {
        let server = test_server();
        let resp = req(
            &server.addr().to_string(),
            vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("stark")),
                ("n", Value::num(32.0)),
                ("b", Value::num(4.0)),
                ("seed", Value::num(7.0)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("leaf_calls").unwrap().as_u64(), Some(49));
        // The response carries its own job's stage metrics, eq. (25) deep.
        let stages = resp.get("stages").unwrap().as_array().unwrap();
        assert_eq!(stages.len(), crate::algos::stark::predicted_stages(4));
        // Frobenius must match a local computation of the same workload.
        let a = DenseMatrix::random(32, 32, 7);
        let b = DenseMatrix::random(32, 32, 8);
        let want = crate::matrix::matmul_blocked(&a, &b).frobenius();
        let got = resp.get("frobenius").unwrap().as_f64().unwrap();
        assert!((want - got).abs() < 1e-9, "{want} vs {got}");
    }

    #[test]
    fn multiply_inline_matrices_returns_product() {
        let server = test_server();
        let resp = req(
            &server.addr().to_string(),
            vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("marlin")),
                ("b", Value::num(2.0)),
                ("a", json::parse("[[1,2],[3,4]]").unwrap()),
                ("b_mat", json::parse("[[1,0],[0,1]]").unwrap()),
                ("return_c", Value::Bool(true)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let c = resp.get("c").unwrap();
        assert_eq!(c.to_json(), "[[1,2],[3,4]]");
    }

    #[test]
    fn submit_wait_status_jobs_lifecycle() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("submit")),
                ("algo", Value::str("stark")),
                ("n", Value::num(16.0)),
                ("b", Value::num(2.0)),
                ("seed", Value::num(3.0)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let id = resp.get("job_id").unwrap().as_u64().unwrap();
        assert!(matches!(resp.get("status").unwrap().as_str(), Some("queued")));

        let done = req(
            &addr,
            vec![("op", Value::str("wait")), ("job_id", Value::num(id as f64))],
        );
        assert_eq!(done.get("ok"), Some(&Value::Bool(true)), "{done:?}");
        assert_eq!(done.get("job_id").unwrap().as_u64(), Some(id));
        assert_eq!(
            done.get("stages").unwrap().as_array().unwrap().len(),
            crate::algos::stark::predicted_stages(2)
        );

        let status = req(
            &addr,
            vec![("op", Value::str("status")), ("job_id", Value::num(id as f64))],
        );
        assert_eq!(status.get("status").unwrap().as_str(), Some("done"));
        assert!(status.get("result").is_some());

        let jobs = req(&addr, vec![("op", Value::str("jobs"))]);
        assert_eq!(jobs.get("ok"), Some(&Value::Bool(true)));
        let list = jobs.get("jobs").unwrap().as_array().unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("job_id").unwrap().as_u64(), Some(id));
        assert_eq!(list[0].get("status").unwrap().as_str(), Some("done"));
    }

    #[test]
    fn admission_control_rejects_busy() {
        let mut state = test_state();
        state.max_inflight_jobs = 1;
        state.job_runners = 1;
        let server = Server::start("127.0.0.1:0", state).unwrap();
        let addr = server.addr().to_string();
        // First submit fills the single in-flight slot.
        let first = req(
            &addr,
            vec![
                ("op", Value::str("submit")),
                ("n", Value::num(64.0)),
                ("b", Value::num(4.0)),
            ],
        );
        assert_eq!(first.get("ok"), Some(&Value::Bool(true)), "{first:?}");
        let id = first.get("job_id").unwrap().as_u64().unwrap();
        // Second submit must bounce with a proper busy rejection.
        let second = req(
            &addr,
            vec![("op", Value::str("submit")), ("n", Value::num(8.0)), ("b", Value::num(2.0))],
        );
        assert_eq!(second.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(second.get("busy"), Some(&Value::Bool(true)), "{second:?}");
        // Once the slot drains, submission works again.
        let done = req(&addr, vec![("op", Value::str("wait")), ("job_id", Value::num(id as f64))]);
        assert_eq!(done.get("ok"), Some(&Value::Bool(true)), "{done:?}");
        let third = req(
            &addr,
            vec![("op", Value::str("submit")), ("n", Value::num(8.0)), ("b", Value::num(2.0))],
        );
        assert_eq!(third.get("ok"), Some(&Value::Bool(true)), "{third:?}");
    }

    #[test]
    fn bad_requests_get_error_responses() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = req(&addr, vec![("op", Value::str("nonsense"))]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        let resp = req(&addr, vec![("op", Value::str("multiply"))]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("\"n\""));
        // Malformed submits are rejected at submit time, not queued.
        let resp = req(
            &addr,
            vec![("op", Value::str("submit")), ("n", Value::num(8.0)), ("b", Value::num(3.0))],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("power-of-two"));
        // status/wait on unknown ids reject TYPED instead of hanging:
        // {"ok":false,"unknown_job":true} so clients branch without
        // string-matching.
        let resp = req(
            &addr,
            vec![("op", Value::str("status")), ("job_id", Value::num(999.0))],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(resp.get("unknown_job"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("job_id").unwrap().as_u64(), Some(999));
        let resp = req(
            &addr,
            vec![("op", Value::str("wait")), ("job_id", Value::num(999.0))],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(resp.get("unknown_job"), Some(&Value::Bool(true)), "{resp:?}");
    }

    #[test]
    fn wait_timeout_returns_instead_of_hanging() {
        // A 1 ms wait on a job that takes orders of magnitude longer
        // (n=256 distributed, debug build) must time out, not block.
        let mut state = test_state();
        state.job_runners = 1;
        let mut server = Server::start("127.0.0.1:0", state).unwrap();
        let addr = server.addr().to_string();
        let resp = req(
            &addr,
            vec![("op", Value::str("submit")), ("n", Value::num(256.0)), ("b", Value::num(2.0))],
        );
        let id = resp.get("job_id").unwrap().as_u64().unwrap();
        let waited = req(
            &addr,
            vec![
                ("op", Value::str("wait")),
                ("job_id", Value::num(id as f64)),
                ("timeout_ms", Value::num(1.0)),
            ],
        );
        assert_eq!(waited.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(waited.get("timeout"), Some(&Value::Bool(true)), "{waited:?}");
        // An unbounded wait still completes the job normally afterwards.
        let done = req(&addr, vec![("op", Value::str("wait")), ("job_id", Value::num(id as f64))]);
        assert_eq!(done.get("ok"), Some(&Value::Bool(true)), "{done:?}");
        server.stop();
    }

    #[test]
    fn finished_jobs_are_evicted_in_completion_order() {
        let last = MAX_FINISHED_JOBS as u64 + 2;
        let mut jobs = Jobs {
            seq: 0,
            entries: BTreeMap::new(),
            queue: VecDeque::new(),
            finished_order: VecDeque::new(),
            inflight: 0,
            accepting: true,
        };
        for id in 1..=last {
            jobs.entries.insert(
                id,
                JobEntry { name: format!("j{id}"), status: JobStatus::Running, spec: None },
            );
            jobs.inflight += 1;
        }
        // A queued job must never be evicted, however old.
        jobs.entries
            .insert(0, JobEntry { name: "queued".into(), status: JobStatus::Queued, spec: None });
        // Ids 2.. finish first; the EARLIEST-submitted job (id 1)
        // finishes LAST — it must survive even though its id is lowest.
        for id in 2..=last {
            finish_job(&mut jobs, id, JobStatus::Done(Arc::new(Value::Bool(true))));
        }
        finish_job(&mut jobs, 1, JobStatus::Done(Arc::new(Value::Bool(true))));
        assert_eq!(jobs.finished_order.len(), MAX_FINISHED_JOBS);
        assert!(jobs.entries.contains_key(&0), "queued jobs are never evicted");
        assert!(
            jobs.entries.contains_key(&1),
            "the most recent FINISHER must survive regardless of submission order"
        );
        // The two earliest finishers (ids 2 and 3) rolled off.
        assert!(!jobs.entries.contains_key(&2));
        assert!(!jobs.entries.contains_key(&3));
        assert!(jobs.entries.contains_key(&last));
    }

    #[test]
    fn shutdown_stops_server() {
        let mut server = test_server();
        let addr = server.addr().to_string();
        let resp = req(&addr, vec![("op", Value::str("shutdown"))]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        server.stop();
        // Further connections may connect (OS backlog) but the accept
        // loop is gone; just assert stop() returned.
    }

    #[test]
    fn stop_joins_handlers_for_idle_connections() {
        // An open connection that never sends a request must not block
        // shutdown past the drain deadline: stop() force-closes it and
        // joins the handler.
        let mut server = test_server();
        let idle = TcpStream::connect(server.addr()).unwrap();
        let started = Instant::now();
        server.stop();
        assert!(
            started.elapsed() < DRAIN_DEADLINE + Duration::from_secs(5),
            "stop() hung on an idle connection"
        );
        drop(idle);
    }

    #[test]
    fn plan_op_reports_planner_choice() {
        let server = test_server();
        let addr = server.addr().to_string();
        // Auto everything: 2 cores, n=256 sits on the baseline side of
        // the crossover at the default calibration.
        let resp = req(&addr, vec![("op", Value::str("plan")), ("n", Value::num(256.0))]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let algo = resp.get("algorithm").unwrap().as_str().unwrap();
        assert_ne!(algo, "auto", "plan must resolve to a concrete system");
        assert_ne!(algo, "stark", "n=256 is baseline territory");
        assert!(resp.get("b").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(resp.get("n").unwrap().as_u64(), Some(256));
        assert!(resp.get("predicted_wall_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(!resp.get("considered").unwrap().as_array().unwrap().is_empty());
        assert!(!resp.get("stages").unwrap().as_array().unwrap().is_empty());
        // Constrained plan: fixed algorithm, planner picks b only.
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("plan")),
                ("n", Value::num(256.0)),
                ("algo", Value::str("stark")),
            ],
        );
        assert_eq!(resp.get("algorithm").unwrap().as_str(), Some("stark"));
        // Invalid combinations come back as protocol errors, not panics.
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("plan")),
                ("n", Value::num(64.0)),
                ("algo", Value::str("stark")),
                ("b", Value::num(3.0)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
    }

    #[test]
    fn auto_submit_runs_planner_choice() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("auto")),
                ("b", Value::str("auto")),
                ("n", Value::num(32.0)),
                ("seed", Value::num(11.0)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("algo").unwrap().as_str(), Some("auto"));
        let ran = resp.get("algorithm").unwrap().as_str().unwrap();
        assert!(["stark", "marlin", "mllib"].contains(&ran), "{ran}");
        assert!(resp.get("b").unwrap().as_u64().unwrap() >= 1);
        // Product correctness via frobenius against a local reference.
        let a = DenseMatrix::random(32, 32, 11);
        let b = DenseMatrix::random(32, 32, 12);
        let want = crate::matrix::matmul_blocked(&a, &b).frobenius();
        let got = resp.get("frobenius").unwrap().as_f64().unwrap();
        assert!((want - got).abs() < 1e-9, "{want} vs {got}");
    }

    #[test]
    fn expression_request_runs_chained_with_one_collect() {
        let server = test_server();
        // (A·B + C)·Aᵀ over inline 2×2 matrices.
        let expr = json::parse(
            r#"{"mul":[{"add":[{"mul":[{"matrix":[[1,2],[3,4]]},{"matrix":[[1,0],[0,1]]}]},{"matrix":[[1,1],[1,1]]}]},{"t":{"matrix":[[1,2],[3,4]]}}]}"#,
        )
        .unwrap();
        let resp = req(
            &server.addr().to_string(),
            vec![
                ("op", Value::str("multiply")),
                ("expr", expr),
                ("return_c", Value::Bool(true)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("algo").unwrap().as_str(), Some("expr"));
        assert_eq!(resp.get("collects").unwrap().as_u64(), Some(1));
        assert_eq!(
            resp.get("multiplies").unwrap().as_array().unwrap().len(),
            2,
            "{resp:?}"
        );
        // ((A·B)+C)·Aᵀ with A=[[1,2],[3,4]], B=I, C=ones:
        // S = [[2,3],[4,5]]; S·Aᵀ = [[8,18],[14,32]].
        assert_eq!(resp.get("c").unwrap().to_json(), "[[8,18],[14,32]]");
        // Malformed trees are rejected at submit time.
        let bad = req(
            &server.addr().to_string(),
            vec![
                ("op", Value::str("submit")),
                ("expr", json::parse(r#"{"pow":[{"gen":{"n":4}},0]}"#).unwrap()),
            ],
        );
        assert_eq!(bad.get("ok"), Some(&Value::Bool(false)), "{bad:?}");
        let bad = req(
            &server.addr().to_string(),
            vec![("op", Value::str("submit")), ("expr", json::parse(r#"{"nope":1}"#).unwrap())],
        );
        assert_eq!(bad.get("ok"), Some(&Value::Bool(false)), "{bad:?}");
        // The leaf budget refuses oversized trees at parse time.
        let many: Vec<String> =
            (0..=MAX_EXPR_LEAVES).map(|i| format!(r#"{{"gen":{{"n":4,"seed":{i}}}}}"#)).collect();
        let too_many = format!(r#"{{"add":[{}]}}"#, many.join(","));
        let bad = req(
            &server.addr().to_string(),
            vec![("op", Value::str("submit")), ("expr", json::parse(&too_many).unwrap())],
        );
        assert_eq!(bad.get("ok"), Some(&Value::Bool(false)), "{bad:?}");
        assert!(bad.get("error").unwrap().as_str().unwrap().contains("leaves"), "{bad:?}");
    }

    #[test]
    fn deadline_ms_zero_times_out_and_server_keeps_serving() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("n", Value::num(64.0)),
                ("b", Value::num(2.0)),
                ("deadline_ms", Value::num(0.0)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("timed out"), "{err}");
        // The timeout is clean: the next job on the same cluster runs fine.
        let ok = req(
            &addr,
            vec![("op", Value::str("multiply")), ("n", Value::num(16.0)), ("b", Value::num(2.0))],
        );
        assert_eq!(ok.get("ok"), Some(&Value::Bool(true)), "{ok:?}");
        // Counters ride on every result document.
        let tasks = ok.get("tasks").unwrap().as_u64().unwrap();
        assert_eq!(ok.get("attempts").unwrap().as_u64(), Some(tasks), "chaos-free: no retries");
        assert_eq!(ok.get("recomputed_partitions").unwrap().as_u64(), Some(0));
        assert_eq!(ok.get("speculative_wins").unwrap().as_u64(), Some(0));
        // `jobs` reports the failed job and the per-job counters.
        let jobs = req(&addr, vec![("op", Value::str("jobs"))]);
        assert_eq!(jobs.get("failed_jobs").unwrap().as_u64(), Some(1), "{jobs:?}");
    }

    #[test]
    fn rectangular_inline_multiply() {
        let server = test_server();
        let resp = req(
            &server.addr().to_string(),
            vec![
                ("op", Value::str("multiply")),
                ("b", Value::num(2.0)),
                ("a", json::parse("[[1,2,3],[4,5,6]]").unwrap()),
                ("b_mat", json::parse("[[1],[1],[1]]").unwrap()),
                ("return_c", Value::Bool(true)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("c").unwrap().to_json(), "[[6],[15]]");
    }

    #[test]
    fn store_ops_roundtrip_with_ref_operands() {
        let server = test_server();
        let addr = server.addr().to_string();
        // put A (seed 5) and B (seed 6) — the same pair `multiply` with
        // n=16 seed=5 would generate, so the re-upload path is the
        // bit-identity reference below.
        for (name, seed) in [("A", 5.0), ("B", 6.0)] {
            let resp = req(
                &addr,
                vec![
                    ("op", Value::str("put")),
                    ("name", Value::str(name)),
                    (
                        "gen",
                        Value::obj(vec![
                            ("n", Value::num(16.0)),
                            ("seed", Value::num(seed)),
                        ]),
                    ),
                ],
            );
            assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
            assert_eq!(resp.get("rows").unwrap().as_u64(), Some(16));
            assert_eq!(resp.get("deduped"), Some(&Value::Bool(false)));
            assert!(resp.get("store").is_some(), "{resp:?}");
        }
        // N=3 jobs referencing the names: the store splits each operand
        // exactly once (splits_computed == 2 on every response).
        let expr = json::parse(r#"{"mul":[{"ref":"A"},{"ref":"B"}],"algo":"stark","b":2}"#)
            .unwrap();
        let mut frobs = Vec::new();
        for _ in 0..3 {
            let resp = req(
                &addr,
                vec![("op", Value::str("multiply")), ("expr", expr.clone())],
            );
            assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
            frobs.push(resp.get("frobenius").unwrap().as_f64().unwrap());
            let store = resp.get("store").unwrap();
            assert_eq!(
                store.get("splits_computed").unwrap().as_u64(),
                Some(2),
                "one split per stored operand, however many jobs: {resp:?}"
            );
        }
        assert!(frobs.windows(2).all(|w| w[0] == w[1]), "{frobs:?}");
        // Direct `{"ref":...}` operands (no expr tree) hit the same cache.
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("stark")),
                ("b", Value::num(2.0)),
                ("a", json::parse(r#"{"ref":"A"}"#).unwrap()),
                ("b_mat", json::parse(r#"{"ref":"B"}"#).unwrap()),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("frobenius").unwrap().as_f64(), Some(frobs[0]));
        // Re-upload path: identical generated operands, bit-identical C.
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("stark")),
                ("b", Value::num(2.0)),
                ("n", Value::num(16.0)),
                ("seed", Value::num(5.0)),
            ],
        );
        assert_eq!(resp.get("frobenius").unwrap().as_f64(), Some(frobs[0]), "{resp:?}");
        // ls sees both names; drop unbinds; get then rejects typed.
        let ls = req(&addr, vec![("op", Value::str("ls"))]);
        let entries = ls.get("entries").unwrap().as_array().unwrap();
        assert_eq!(entries.len(), 2, "{ls:?}");
        assert_eq!(entries[0].get("name").unwrap().as_str(), Some("A"));
        assert_eq!(entries[0].get("splits_computed").unwrap().as_u64(), Some(1));
        let dropped = req(
            &addr,
            vec![("op", Value::str("drop")), ("name", Value::str("A"))],
        );
        assert_eq!(dropped.get("ok"), Some(&Value::Bool(true)), "{dropped:?}");
        assert_eq!(dropped.get("dropped"), Some(&Value::Bool(true)));
        assert_eq!(dropped.get("pinned"), Some(&Value::Bool(false)));
        let gone = req(
            &addr,
            vec![
                ("op", Value::str("get")),
                ("name", Value::str("A")),
                ("values", Value::Bool(true)),
            ],
        );
        assert_eq!(gone.get("ok"), Some(&Value::Bool(false)));
        assert_eq!(gone.get("unknown_name"), Some(&Value::Bool(true)), "{gone:?}");
        // B is still there, values round-trip through `get`.
        let b = req(
            &addr,
            vec![
                ("op", Value::str("get")),
                ("name", Value::str("B")),
                ("values", Value::Bool(true)),
            ],
        );
        assert_eq!(b.get("ok"), Some(&Value::Bool(true)), "{b:?}");
        let values = b.get("values").unwrap();
        let want = matrix_to_json(&DenseMatrix::random(16, 16, 6));
        assert_eq!(values.to_json(), want.to_json());
    }

    #[test]
    fn dangling_ref_is_rejected_with_a010_at_submit() {
        let server = test_server();
        let addr = server.addr().to_string();
        let expr = json::parse(r#"{"mul":[{"ref":"never-put"},{"gen":{"n":4}}]}"#).unwrap();
        let resp = req(&addr, vec![("op", Value::str("submit")), ("expr", expr)]);
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("STARK-A010"), "{err}");
        assert!(err.contains("never-put"), "{err}");
        // Unknown refs as direct multiply operands reject typed too
        // (no expr tree, so the raw store error carries the context).
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("a", json::parse(r#"{"ref":"never-put"}"#).unwrap()),
                ("b_mat", json::parse("[[1]]").unwrap()),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
        assert!(
            resp.get("error").unwrap().as_str().unwrap().contains("never-put"),
            "{resp:?}"
        );
    }

    #[test]
    fn solve_expression_over_store_refs() {
        let server = test_server();
        let addr = server.addr().to_string();
        let n = 8usize;
        let r = DenseMatrix::random(n, n, 41);
        let s_mat = DenseMatrix::from_fn(n, n, |i, j| {
            if i == j { r.get(i, j) + n as f64 } else { r.get(i, j) }
        });
        let b_mat = DenseMatrix::random(n, n, 43);
        for (name, m) in [("S", &s_mat), ("B", &b_mat)] {
            let resp = req(
                &addr,
                vec![
                    ("op", Value::str("put")),
                    ("name", Value::str(name)),
                    ("matrix", matrix_to_json(m)),
                ],
            );
            assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        }
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("expr", json::parse(r#"{"solve":[{"ref":"S"},{"ref":"B"}]}"#).unwrap()),
                ("return_c", Value::Bool(true)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let inv = resp.get("inversions").unwrap().as_array().unwrap();
        assert_eq!(inv.len(), 1, "{resp:?}");
        assert_eq!(inv[0].get("label").unwrap().as_str(), Some("inv1"));
        assert_eq!(resp.get("collects").unwrap().as_u64(), Some(1), "{resp:?}");
        // A·X ≈ B — the solve actually solved.
        let x = parse_matrix(resp.get("c").unwrap()).unwrap();
        assert!(crate::matrix::matmul_naive(&s_mat, &x).allclose(&b_mat, 1e-8));
        // Both operands resolved through the store.
        let hits = resp.get("store").unwrap().get("hits").unwrap().as_u64().unwrap();
        assert!(hits >= 2, "{resp:?}");
    }

    #[test]
    fn singular_inverse_is_a_typed_failure_not_a_wedge() {
        let server = test_server();
        let addr = server.addr().to_string();
        // Rank-1: row 2 is twice row 1.
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("expr", json::parse(r#"{"inv":{"matrix":[[1,2],[2,4]]}}"#).unwrap()),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "{resp:?}");
        let err = resp.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("singular"), "{err}");
        // The failure was a clean job error, not a wedged runner: the
        // same server still executes the next job.
        let ok = req(
            &addr,
            vec![("op", Value::str("multiply")), ("n", Value::num(8.0)), ("b", Value::num(2.0))],
        );
        assert_eq!(ok.get("ok"), Some(&Value::Bool(true)), "{ok:?}");
    }

    #[test]
    fn signed_pow_grammar() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = req(
            &addr,
            vec![
                ("op", Value::str("multiply")),
                ("expr", json::parse(r#"{"pow":[{"matrix":[[2,0],[0,4]]},-1]}"#).unwrap()),
                ("return_c", Value::Bool(true)),
            ],
        );
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let c = parse_matrix(resp.get("c").unwrap()).unwrap();
        assert!((c.get(0, 0) - 0.5).abs() < 1e-12, "{resp:?}");
        assert!((c.get(1, 1) - 0.25).abs() < 1e-12, "{resp:?}");
        // Non-integer and out-of-range exponents are rejected at parse.
        for k in ["1.5", "65", "-65"] {
            let tree = json::parse(&format!(r#"{{"pow":[{{"gen":{{"n":4}}}},{k}]}}"#)).unwrap();
            let bad = req(&addr, vec![("op", Value::str("submit")), ("expr", tree)]);
            assert_eq!(bad.get("ok"), Some(&Value::Bool(false)), "k={k}: {bad:?}");
            assert!(
                bad.get("error").unwrap().as_str().unwrap().contains("integer in -64..=64"),
                "k={k}: {bad:?}"
            );
        }
    }
}
