//! `stark serve` — the coordinator as a long-running service.
//!
//! The paper motivates Stark as one step inside larger analytics
//! workflows; this module exposes the multiply engine over a socket so
//! other processes can use it like a service (vLLM-router-style: a
//! leader process owning the simulated cluster + compiled artifacts,
//! clients submitting work).
//!
//! Protocol: newline-delimited JSON over TCP.
//!
//! ```json
//! -> {"op":"ping"}
//! <- {"ok":true,"service":"stark","version":"0.1.0"}
//!
//! -> {"op":"multiply","algo":"stark","n":256,"b":4,"seed":7}
//! <- {"ok":true,"wall_ms":12.3,"leaf_calls":49,"frobenius":148.8,...}
//!
//! -> {"op":"multiply","algo":"stark","b":2,
//!     "a":[[1,2],[3,4]],"b_mat":[[1,0],[0,1]],"return_c":true}
//! <- {"ok":true,"c":[[1,2],[3,4]],...}
//!
//! -> {"op":"shutdown"}
//! ```
//!
//! One request is served per connection-line, synchronously; concurrent
//! connections each get a handler thread while the simulated cluster and
//! the PJRT artifact cache are shared behind the server state.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::algos::{self, Algorithm, StarkConfig};
use crate::engine::SparkContext;
use crate::matrix::DenseMatrix;
use crate::runtime::LeafBackend;
use crate::util::json::{self, Value};

/// Shared server state: the simulated cluster and the leaf backend.
pub struct ServerState {
    pub ctx: SparkContext,
    pub backend: Arc<dyn LeafBackend>,
    pub default_b: usize,
}

/// A running server handle.
pub struct Server {
    addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `host:port` (port 0 = ephemeral) and start accepting.
    pub fn start(addr: &str, state: ServerState) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(state);
        let flag = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name("stark-serve-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            let st = state.clone();
                            let fl = flag.clone();
                            let _ = std::thread::Builder::new()
                                .name("stark-serve-conn".into())
                                .spawn(move || {
                                    let _ = handle_connection(s, &st, &fl);
                                });
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self { addr: local, shutdown, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Signal shutdown and unblock the accept loop.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // wake the accept loop
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(
    stream: TcpStream,
    state: &ServerState,
    shutdown: &AtomicBool,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(&line, state, shutdown) {
            Ok(v) => v,
            Err(e) => Value::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", Value::str(format!("{e:#}"))),
            ]),
        };
        writer.write_all(response.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = peer;
    Ok(())
}

fn parse_matrix(v: &Value) -> Result<DenseMatrix> {
    let rows = v.as_array().context("matrix must be an array of rows")?;
    anyhow::ensure!(!rows.is_empty(), "empty matrix");
    let mut data = Vec::new();
    let cols = rows[0].as_array().context("row must be an array")?.len();
    for row in rows {
        let row = row.as_array().context("row must be an array")?;
        anyhow::ensure!(row.len() == cols, "ragged matrix");
        for x in row {
            data.push(x.as_f64().context("matrix element must be a number")?);
        }
    }
    Ok(DenseMatrix::from_vec(rows.len(), cols, data))
}

fn matrix_to_json(m: &DenseMatrix) -> Value {
    Value::Array(
        (0..m.rows())
            .map(|r| Value::Array((0..m.cols()).map(|c| Value::num(m.get(r, c))).collect()))
            .collect(),
    )
}

/// Handle one request line, producing the response document.
pub fn handle_request(line: &str, state: &ServerState, shutdown: &AtomicBool) -> Result<Value> {
    let req = json::parse(line).map_err(|e| anyhow::anyhow!("bad request JSON: {e}"))?;
    let op = req.get("op").and_then(Value::as_str).context("missing \"op\"")?;
    match op {
        "ping" => Ok(Value::obj(vec![
            ("ok", Value::Bool(true)),
            ("service", Value::str("stark")),
            ("version", Value::str(env!("CARGO_PKG_VERSION"))),
            ("backend", Value::str(state.backend.name())),
        ])),
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Value::obj(vec![("ok", Value::Bool(true)), ("stopping", Value::Bool(true))]))
        }
        "multiply" => {
            let algo: Algorithm = req
                .get("algo")
                .and_then(Value::as_str)
                .unwrap_or("stark")
                .parse()
                .map_err(anyhow::Error::msg)?;
            let b = req.get("b").and_then(Value::as_usize).unwrap_or(state.default_b);
            let (a, bm) = match (req.get("a"), req.get("b_mat")) {
                (Some(a), Some(bm)) => (parse_matrix(a)?, parse_matrix(bm)?),
                _ => {
                    let n = req.get("n").and_then(Value::as_usize).context(
                        "provide either inline \"a\"/\"b_mat\" or a size \"n\"",
                    )?;
                    let seed = req.get("seed").and_then(Value::as_u64).unwrap_or(42);
                    (DenseMatrix::random(n, n, seed), DenseMatrix::random(n, n, seed + 1))
                }
            };
            let out = algos::multiply_general(
                algo,
                &state.ctx,
                state.backend.clone(),
                &a,
                &bm,
                b,
                &StarkConfig::default(),
            );
            let mut fields = vec![
                ("ok", Value::Bool(true)),
                ("algo", Value::str(algo.to_string())),
                ("rows", Value::num(out.c.rows() as f64)),
                ("cols", Value::num(out.c.cols() as f64)),
                ("wall_ms", Value::num(out.job.wall_ms)),
                ("leaf_calls", Value::num(out.leaf_calls as f64)),
                ("leaf_ms", Value::num(out.leaf_ms)),
                ("frobenius", Value::num(out.c.frobenius())),
                (
                    "shuffle_bytes",
                    Value::num(out.job.total_shuffle_bytes() as f64),
                ),
            ];
            if req.get("return_c").and_then(Value::as_bool).unwrap_or(false) {
                fields.push(("c", matrix_to_json(&out.c)));
            }
            Ok(Value::obj(fields))
        }
        other => anyhow::bail!("unknown op {other:?}"),
    }
}

/// Simple blocking client: send one request line, read one response.
pub fn request(addr: &str, body: &Value) -> Result<Value> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.write_all(body.to_json().as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::engine::ClusterConfig;

    fn test_server() -> Server {
        let state = ServerState {
            ctx: SparkContext::new(ClusterConfig::new(2, 1)),
            backend: crate::config::build_backend(BackendKind::Packed, 1).unwrap(),
            default_b: 2,
        };
        Server::start("127.0.0.1:0", state).unwrap()
    }

    #[test]
    fn ping_roundtrip() {
        let server = test_server();
        let resp = request(&server.addr().to_string(), &Value::obj(vec![("op", Value::str("ping"))]))
            .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        assert_eq!(resp.get("service").unwrap().as_str(), Some("stark"));
    }

    #[test]
    fn multiply_by_seed() {
        let server = test_server();
        let resp = request(
            &server.addr().to_string(),
            &Value::obj(vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("stark")),
                ("n", Value::num(32.0)),
                ("b", Value::num(4.0)),
                ("seed", Value::num(7.0)),
            ]),
        )
        .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("leaf_calls").unwrap().as_u64(), Some(49));
        // Frobenius must match a local computation of the same workload.
        let a = DenseMatrix::random(32, 32, 7);
        let b = DenseMatrix::random(32, 32, 8);
        let want = crate::matrix::matmul_blocked(&a, &b).frobenius();
        let got = resp.get("frobenius").unwrap().as_f64().unwrap();
        assert!((want - got).abs() < 1e-9, "{want} vs {got}");
    }

    #[test]
    fn multiply_inline_matrices_returns_product() {
        let server = test_server();
        let resp = request(
            &server.addr().to_string(),
            &Value::obj(vec![
                ("op", Value::str("multiply")),
                ("algo", Value::str("marlin")),
                ("b", Value::num(2.0)),
                (
                    "a",
                    json::parse("[[1,2],[3,4]]").unwrap(),
                ),
                ("b_mat", json::parse("[[1,0],[0,1]]").unwrap()),
                ("return_c", Value::Bool(true)),
            ]),
        )
        .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        let c = resp.get("c").unwrap();
        assert_eq!(c.to_json(), "[[1,2],[3,4]]");
    }

    #[test]
    fn bad_requests_get_error_responses() {
        let server = test_server();
        let addr = server.addr().to_string();
        let resp = request(&addr, &Value::obj(vec![("op", Value::str("nonsense"))])).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        let resp = request(&addr, &Value::obj(vec![("op", Value::str("multiply"))])).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(false)));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("\"n\""));
    }

    #[test]
    fn shutdown_stops_server() {
        let mut server = test_server();
        let addr = server.addr().to_string();
        let resp = request(&addr, &Value::obj(vec![("op", Value::str("shutdown"))])).unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)));
        server.stop();
        // Further connections may connect (OS backlog) but the accept
        // loop is gone; just assert stop() returned.
    }

    #[test]
    fn rectangular_inline_multiply() {
        let server = test_server();
        let resp = request(
            &server.addr().to_string(),
            &Value::obj(vec![
                ("op", Value::str("multiply")),
                ("b", Value::num(2.0)),
                ("a", json::parse("[[1,2,3],[4,5,6]]").unwrap()),
                ("b_mat", json::parse("[[1],[1],[1]]").unwrap()),
                ("return_c", Value::Bool(true)),
            ]),
        )
        .unwrap();
        assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("c").unwrap().to_json(), "[[6],[15]]");
    }
}
