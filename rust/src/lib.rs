//! # Stark — distributed Strassen matrix multiplication
//!
//! A Rust + JAX + Pallas reproduction of *"Stark: Fast and Scalable
//! Strassen's Matrix Multiplication using Apache Spark"* (Misra,
//! Bhattacharya & Ghosh, 2018) — grown into a small distributed
//! matrix-multiplication *system* with sessions, a cost-model planner,
//! and a job-queue service.
//!
//! ## The front door: sessions and handles
//!
//! All workloads enter through [`api::StarkSession`]:
//!
//! ```no_run
//! use stark::api::StarkSession;
//! use stark::matrix::DenseMatrix;
//!
//! let session = StarkSession::builder().build()?;       // cluster + backend + planner
//! let a = session.matrix(&DenseMatrix::random(300, 300, 1));
//! let b = session.matrix(&DenseMatrix::random(300, 300, 2));
//! let report = a.multiply(&b).collect()?;               // planner picks algorithm AND b
//! println!("{} b={} wall={:.1}ms", report.plan.algorithm, report.plan.b, report.job.wall_ms);
//! # Ok::<(), stark::StarkError>(())
//! ```
//!
//! - operands of **any shape** are zero-padded in, and the true product
//!   sliced back out, automatically;
//! - [`api::DistMatrix`] handles cache their block distribution across
//!   jobs — multiply one `A` against many `B`s without re-distributing;
//! - `Algorithm::Auto` / `Splits::Auto` route through [`cost::Planner`],
//!   the paper's §IV analytic model with calibrated `(α, β)`; ask it
//!   directly with `session.plan(n)`;
//! - errors are typed ([`StarkError`]), never process aborts.
//!
//! ## Pipelines: the expression DAG
//!
//! Chains of operations are **lazy expressions** ([`api::DistExpr`])
//! that plan as a whole and collect **once** — intermediates stay
//! distributed as block RDDs between multiplies:
//!
//! ```no_run
//! use stark::api::StarkSession;
//! use stark::matrix::DenseMatrix;
//!
//! let s = StarkSession::builder().build()?;
//! let (a, b) = (s.matrix(&DenseMatrix::random(200, 200, 1)),
//!               s.matrix(&DenseMatrix::random(200, 200, 2)));
//! let (c, d) = (s.matrix(&DenseMatrix::random(200, 200, 3)),
//!               s.matrix(&DenseMatrix::random(200, 200, 4)));
//! // (A·B + C)·Dᵀ: one job, one collect, per-node plans in the report.
//! let report = a.multiply(&b).add(&c).multiply(&d.transpose()).collect()?;
//! assert_eq!(report.plan.expression, "(A·B+C)·Dᵀ");
//! # Ok::<(), stark::StarkError>(())
//! ```
//!
//! `add`/`sub`/`scale`/`transpose`/`pow(k)` compose freely; operand
//! sums fuse into the block split (`(A+B)·C` never allocates `A+B`);
//! associative chains re-order by the §IV model when strictly cheaper.
//! See DESIGN.md S18.
//!
//! ## Layers
//!
//! - [`api`] — sessions, `DistMatrix` handles, the multiply builder.
//! - [`engine`] — `sparklet`, the Spark-like distributed substrate the
//!   algorithms run on (RDDs, stages, shuffle, executor pool, metrics,
//!   fair multi-job scheduling).
//! - [`matrix`] — dense matrices, block partitioning, single-node kernels.
//! - [`algos`] — the paper's contribution ([`algos::stark`]) plus the
//!   Marlin and MLLib baselines, behind the
//!   [`algos::MultiplyAlgorithm`] trait.
//! - [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas leaf
//!   kernels (`artifacts/*.hlo.txt`), plus the native fallback backend.
//! - [`cost`] — the §IV analytic cost model (Tables I–III) and the
//!   [`cost::Planner`] that puts it to work.
//! - [`analyze`] — static lineage/plan analyzer: typed `STARK-Axxx`
//!   diagnostics for tag, alignment, determinism, job-scope and
//!   stage-ledger invariants, checked before anything executes.
//! - [`serve`] — the session exposed as a TCP job queue
//!   (`submit`/`wait`/`plan`/`put`/…).
//! - [`store`] — the named-matrix store: operands resident across jobs
//!   under a byte budget, with LRU eviction, checksummed disk spill,
//!   and restart recovery.
//! - [`config`] — experiment/run configuration shared by the CLI,
//!   examples and benches.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the reproduction of every table and figure.

pub mod algos;
pub mod analyze;
pub mod api;
pub mod config;
pub mod cost;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod matrix;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod util;

pub use analyze::{Diagnostic, Severity};
pub use api::{
    DistExpr, DistMatrix, ExprPlan, ExprReport, IntoExpr, MultiplyBuilder, MultiplyReport,
    SessionBuilder, StarkSession,
};
pub use error::StarkError;
