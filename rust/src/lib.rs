//! # Stark — distributed Strassen matrix multiplication
//!
//! A Rust + JAX + Pallas reproduction of *"Stark: Fast and Scalable
//! Strassen's Matrix Multiplication using Apache Spark"* (Misra,
//! Bhattacharya & Ghosh, 2018).
//!
//! The crate is organized by the paper's own decomposition:
//!
//! - [`engine`] — `sparklet`, the Spark-like distributed substrate the
//!   algorithms run on (RDDs, stages, shuffle, executor pool, metrics).
//! - [`matrix`] — dense matrices, block partitioning, single-node kernels.
//! - [`algos`] — the paper's contribution ([`algos::stark`]) plus the
//!   Marlin and MLLib baselines it evaluates against.
//! - [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas leaf
//!   kernels (`artifacts/*.hlo.txt`), plus the native fallback backend.
//! - [`cost`] — the paper's §IV analytic cost model (Tables I–III).
//! - [`config`] — experiment/run configuration shared by the CLI,
//!   examples and benches.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the reproduction of every table and figure.

pub mod algos;
pub mod config;
pub mod cost;
pub mod engine;
pub mod experiments;
pub mod matrix;
pub mod runtime;
pub mod serve;
pub mod util;
