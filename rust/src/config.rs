//! Run configuration shared by the CLI, examples, benches and tests
//! (DESIGN.md S14).
//!
//! [`RunConfig`] describes one distributed multiply: workload (`n`, `b`,
//! seed), cluster shape (executors × cores, network model), algorithm,
//! and leaf backend. It serializes to/from JSON (via [`crate::util::json`])
//! so experiment harnesses record exactly what ran.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::algos::{Algorithm, StarkConfig};
use crate::cost::Splits;
use crate::engine::{ChaosConfig, ClusterConfig, SchedulerPolicy, SparkContext};
use crate::matrix::multiply::Kernel;
use crate::runtime::{ArtifactLibrary, LeafBackend, NativeBackend, XlaBackend, XlaService};
use crate::util::json::Value;

/// Which leaf backend multiplies blocks at the bottom of the recursion —
/// the single selector threaded from the CLI (`--backend`) through every
/// algorithm. The three pure-Rust kernels are the ablation ladder of
/// EXPERIMENTS.md §Perf change 6 (`stark_bench kernel`); they produce
/// bit-identical products, so switching between them never changes a
/// distributed result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust textbook `ikj` kernel (ablation baseline).
    Naive,
    /// Pure-Rust cache-blocked `ikj` kernel (the pre-PR native default).
    Blocked,
    /// Pure-Rust packed register-tiled GEMM with fused Strassen operand
    /// packing (`matrix/gemm.rs`) — the native default.
    Packed,
    /// AOT XLA artifact, `dot` family (plain HLO dot — production
    /// default; stubbed without the `xla` feature).
    Xla,
    /// AOT XLA artifact, `pallas` family (the L1 kernel via interpret
    /// lowering; structure-faithful, slower on CPU — the ablation arm).
    XlaPallas,
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Naive => write!(f, "naive"),
            BackendKind::Blocked => write!(f, "blocked"),
            BackendKind::Packed => write!(f, "packed"),
            BackendKind::Xla => write!(f, "xla"),
            BackendKind::XlaPallas => write!(f, "xla-pallas"),
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(BackendKind::Naive),
            "blocked" => Ok(BackendKind::Blocked),
            // "native" is the pre-kernel-ablation name for the pure-Rust
            // default, kept as an alias so recorded RunConfig JSON and
            // muscle-memory CLI invocations keep working.
            "packed" | "native" => Ok(BackendKind::Packed),
            "xla" => Ok(BackendKind::Xla),
            "xla-pallas" | "pallas" => Ok(BackendKind::XlaPallas),
            other => {
                Err(format!("unknown backend {other:?} (naive|blocked|packed|xla|xla-pallas)"))
            }
        }
    }
}

/// One experiment run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Matrix dimension (padded up as needed; see `Splits::padded_dim`).
    pub n: usize,
    /// Splits per side: a fixed `b`, or `auto` for the planner's choice.
    pub splits: Splits,
    /// Algorithm — may be `Algorithm::Auto` for the planner's choice.
    pub algo: Algorithm,
    pub backend: BackendKind,
    pub executors: usize,
    pub cores_per_executor: usize,
    /// Simulated shuffle bandwidth, bytes/s (None = memory speed).
    pub net_bandwidth: Option<f64>,
    pub seed: u64,
    /// Stark: fuse the last recursion level into one XLA call.
    pub fused_leaf: bool,
    /// Materialize leaf products in their own stage (stage-wise experiments).
    pub isolate_multiply: bool,
    /// Stark: sum signed divide/combine groups map-side (fold-by-key).
    /// `false` selects the group-by-key baseline the paper's cost model
    /// (§IV) transcribes — the arm shuffle-volume comparisons run against.
    pub map_side_combine: bool,
    /// Run the static plan analyzer ([`crate::analyze`]) before executing
    /// expressions even in release builds, rejecting plans with error
    /// diagnostics (debug builds always run it).
    pub strict_analyze: bool,
    /// Sleep for real on the simulated shuffle-read wait (wall-clock
    /// faithful demos); the wait always accrues to the metrics.
    pub real_net_sleep: bool,
    /// Task ordering across concurrent jobs (fair = round-robin across
    /// runnable jobs, the serve-mode default; fifo = one global queue).
    pub scheduler: SchedulerPolicy,
    /// Fair scheduler: how many distinct jobs share the rotation at once.
    pub max_concurrent_jobs: usize,
    /// Optional seeded chaos injection (DESIGN.md S20).
    pub chaos: Option<ChaosConfig>,
    /// Per-task retry budget (first attempt included).
    pub max_task_attempts: u32,
    /// Straggler speculation: duplicate tasks slower than
    /// `multiplier × stage median`; `None` disables speculation.
    pub speculation_multiplier: Option<f64>,
    /// Named-matrix store byte budget (payloads + cached splits);
    /// `None` = unlimited (see [`crate::store`]).
    pub store_byte_budget: Option<u64>,
    /// Directory backing the store's spill files (persists named
    /// matrices across restarts); `None` = ephemeral temp dir.
    pub store_dir: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            n: 256,
            splits: Splits::Fixed(4),
            algo: Algorithm::Stark,
            backend: BackendKind::Packed,
            executors: 2,
            cores_per_executor: 2,
            net_bandwidth: None,
            seed: 42,
            fused_leaf: false,
            isolate_multiply: false,
            map_side_combine: true,
            strict_analyze: false,
            real_net_sleep: false,
            scheduler: SchedulerPolicy::Fair,
            max_concurrent_jobs: 4,
            chaos: None,
            max_task_attempts: 4,
            speculation_multiplier: None,
            store_byte_budget: None,
            store_dir: None,
        }
    }
}

impl RunConfig {
    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig {
            executors: self.executors,
            cores_per_executor: self.cores_per_executor,
            net_bandwidth: self.net_bandwidth,
            real_net_sleep: self.real_net_sleep,
            scheduler: self.scheduler,
            max_concurrent_jobs: self.max_concurrent_jobs,
            chaos: self.chaos.clone(),
            max_task_attempts: self.max_task_attempts,
            speculation_multiplier: self.speculation_multiplier,
            store_byte_budget: self.store_byte_budget,
            store_dir: self.store_dir.clone(),
        }
    }

    pub fn context(&self) -> SparkContext {
        SparkContext::new(self.cluster_config())
    }

    pub fn stark_config(&self) -> StarkConfig {
        StarkConfig {
            fused_leaf: self.fused_leaf,
            isolate_multiply: self.isolate_multiply,
            map_side_combine: self.map_side_combine,
            strict_analyze: self.strict_analyze,
        }
    }

    /// Build the leaf backend. XLA backends need `artifacts/` (built by
    /// `make artifacts`); the service runs one PJRT thread per core so
    /// concurrent leaf tasks don't serialize behind a smaller pool
    /// (EXPERIMENTS.md §Perf, change 3).
    pub fn backend(&self) -> Result<Arc<dyn LeafBackend>> {
        build_backend(self.backend, self.executors * self.cores_per_executor)
    }

    pub fn to_json(&self) -> String {
        let b_field = match self.splits {
            Splits::Fixed(b) => Value::num(b as f64),
            Splits::Auto => Value::str("auto"),
        };
        let mut fields = vec![
            ("n", Value::num(self.n as f64)),
            ("b", b_field),
            ("algo", Value::str(self.algo.to_string())),
            ("backend", Value::str(self.backend.to_string())),
            ("executors", Value::num(self.executors as f64)),
            ("cores_per_executor", Value::num(self.cores_per_executor as f64)),
            (
                "net_bandwidth",
                self.net_bandwidth.map(Value::num).unwrap_or(Value::Null),
            ),
            ("seed", Value::num(self.seed as f64)),
            ("fused_leaf", Value::Bool(self.fused_leaf)),
            ("isolate_multiply", Value::Bool(self.isolate_multiply)),
            ("map_side_combine", Value::Bool(self.map_side_combine)),
            ("strict_analyze", Value::Bool(self.strict_analyze)),
            ("real_net_sleep", Value::Bool(self.real_net_sleep)),
            ("scheduler", Value::str(self.scheduler.to_string())),
            ("max_concurrent_jobs", Value::num(self.max_concurrent_jobs as f64)),
            ("max_task_attempts", Value::num(f64::from(self.max_task_attempts))),
            (
                "speculation_multiplier",
                self.speculation_multiplier.map(Value::num).unwrap_or(Value::Null),
            ),
            (
                "store_byte_budget",
                self.store_byte_budget.map(|b| Value::num(b as f64)).unwrap_or(Value::Null),
            ),
            (
                "store_dir",
                self.store_dir.clone().map(Value::str).unwrap_or(Value::Null),
            ),
        ];
        if let Some(c) = &self.chaos {
            fields.push((
                "chaos",
                Value::obj(vec![
                    ("seed", Value::num(c.seed as f64)),
                    ("fail_rate", Value::num(c.fail_rate)),
                    ("panic_rate", Value::num(c.panic_rate)),
                    ("slow_rate", Value::num(c.slow_rate)),
                    ("slow_factor", Value::num(c.slow_factor)),
                    ("executor_loss_rate", Value::num(c.executor_loss_rate)),
                    (
                        "stage_contains",
                        c.stage_contains.clone().map(Value::str).unwrap_or(Value::Null),
                    ),
                    (
                        "fail_once_partition",
                        c.fail_once_partition.map(|p| Value::num(p as f64)).unwrap_or(Value::Null),
                    ),
                ]),
            ));
        }
        Value::obj(fields).to_json()
    }

    pub fn from_json(s: &str) -> Result<Self> {
        let v = crate::util::json::parse(s).context("parsing RunConfig JSON")?;
        let get_usize = |k: &str| -> Result<usize> {
            v.get(k).and_then(Value::as_usize).with_context(|| format!("missing field {k}"))
        };
        let chaos = match v.get("chaos") {
            Some(c) if *c != Value::Null => Some(ChaosConfig {
                seed: c.get("seed").and_then(Value::as_u64).unwrap_or(0),
                fail_rate: c.get("fail_rate").and_then(Value::as_f64).unwrap_or(0.0),
                panic_rate: c.get("panic_rate").and_then(Value::as_f64).unwrap_or(0.0),
                slow_rate: c.get("slow_rate").and_then(Value::as_f64).unwrap_or(0.0),
                slow_factor: c.get("slow_factor").and_then(Value::as_f64).unwrap_or(4.0),
                executor_loss_rate: c
                    .get("executor_loss_rate")
                    .and_then(Value::as_f64)
                    .unwrap_or(0.0),
                stage_contains: c
                    .get("stage_contains")
                    .and_then(Value::as_str)
                    .map(str::to_string),
                fail_once_partition: c.get("fail_once_partition").and_then(Value::as_usize),
            }),
            // Legacy recorded configs carry a one-shot "failure" object:
            // parse it into the equivalent fail-once chaos spec.
            _ => match v.get("failure") {
                Some(f) if *f != Value::Null => Some(ChaosConfig::fail_once(
                    f.get("stage_contains")
                        .and_then(Value::as_str)
                        .context("failure.stage_contains")?,
                    f.get("partition").and_then(Value::as_usize).context("failure.partition")?,
                )),
                _ => None,
            },
        };
        // "b" is a number for a fixed split count, or the string "auto".
        let splits = match v.get("b") {
            Some(Value::String(s)) => s.parse::<Splits>().map_err(anyhow::Error::msg)?,
            Some(other) => Splits::Fixed(other.as_usize().context("field b")?),
            None => anyhow::bail!("missing field b"),
        };
        Ok(Self {
            n: get_usize("n")?,
            splits,
            algo: v
                .get("algo")
                .and_then(Value::as_str)
                .context("missing algo")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            backend: v
                .get("backend")
                .and_then(Value::as_str)
                .context("missing backend")?
                .parse()
                .map_err(anyhow::Error::msg)?,
            executors: get_usize("executors")?,
            cores_per_executor: get_usize("cores_per_executor")?,
            net_bandwidth: v.get("net_bandwidth").and_then(Value::as_f64),
            seed: v.get("seed").and_then(Value::as_u64).context("missing seed")?,
            fused_leaf: v.get("fused_leaf").and_then(Value::as_bool).unwrap_or(false),
            isolate_multiply: v.get("isolate_multiply").and_then(Value::as_bool).unwrap_or(false),
            map_side_combine: v.get("map_side_combine").and_then(Value::as_bool).unwrap_or(true),
            // Legacy recorded configs predate the analyzer: default off.
            strict_analyze: v.get("strict_analyze").and_then(Value::as_bool).unwrap_or(false),
            real_net_sleep: v.get("real_net_sleep").and_then(Value::as_bool).unwrap_or(false),
            // Pre-scheduler RunConfig JSON carries neither knob: default
            // to the fair policy the cluster itself defaults to.
            scheduler: match v.get("scheduler").and_then(Value::as_str) {
                Some(s) => s.parse().map_err(anyhow::Error::msg)?,
                None => SchedulerPolicy::Fair,
            },
            max_concurrent_jobs: v
                .get("max_concurrent_jobs")
                .and_then(Value::as_usize)
                .unwrap_or(4),
            max_task_attempts: v
                .get("max_task_attempts")
                .and_then(Value::as_u64)
                .map(|a| a as u32)
                .unwrap_or(4),
            speculation_multiplier: v.get("speculation_multiplier").and_then(Value::as_f64),
            // Pre-store recorded configs carry neither knob: unlimited
            // budget, ephemeral spill dir — exactly the old behavior.
            store_byte_budget: v.get("store_byte_budget").and_then(Value::as_u64),
            store_dir: v.get("store_dir").and_then(Value::as_str).map(str::to_string),
            chaos,
        })
    }
}

/// Construct a [`LeafBackend`] of `kind` with `threads` runtime threads
/// for the XLA variants. Threads are capped at the host parallelism —
/// extra PJRT clients on an oversubscribed host only contend
/// (EXPERIMENTS.md §Perf, change 3).
pub fn build_backend(kind: BackendKind, threads: usize) -> Result<Arc<dyn LeafBackend>> {
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = threads.clamp(1, host);
    match kind {
        BackendKind::Naive => Ok(Arc::new(NativeBackend::new(Kernel::Naive))),
        BackendKind::Blocked => Ok(Arc::new(NativeBackend::new(Kernel::Blocked))),
        BackendKind::Packed => Ok(Arc::new(NativeBackend::new(Kernel::Packed))),
        BackendKind::Xla | BackendKind::XlaPallas => {
            let dir = crate::runtime::find_artifacts_dir().context(
                "artifacts/manifest.json not found — run `make artifacts` \
                 (or set STARK_ARTIFACTS)",
            )?;
            let lib = ArtifactLibrary::load(&dir)?;
            let impl_ = if kind == BackendKind::Xla { "dot" } else { "pallas" };
            let svc = Arc::new(XlaService::new(lib, threads, impl_)?);
            Ok(Arc::new(XlaBackend::new(svc)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let cfg = RunConfig::default();
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.n, cfg.n);
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.net_bandwidth, None);
        assert!(back.chaos.is_none());
        assert_eq!(back.max_task_attempts, 4);
        assert!(back.speculation_multiplier.is_none());
        assert!(back.map_side_combine, "map-side combining is the default");
        assert!(!back.strict_analyze, "strict analyze is opt-in");
        assert!(!back.real_net_sleep);
        assert_eq!(back.scheduler, SchedulerPolicy::Fair);
        assert_eq!(back.max_concurrent_jobs, 4);
    }

    #[test]
    fn scheduler_knobs_roundtrip_and_default_on_old_json() {
        let cfg = RunConfig {
            scheduler: SchedulerPolicy::Fifo,
            max_concurrent_jobs: 9,
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.scheduler, SchedulerPolicy::Fifo);
        assert_eq!(back.max_concurrent_jobs, 9);
        // Pre-scheduler recorded configs (no knobs) keep parsing.
        let legacy = r#"{"n":64,"b":2,"algo":"stark","backend":"packed",
            "executors":2,"cores_per_executor":2,"seed":1}"#;
        let parsed = RunConfig::from_json(legacy).unwrap();
        assert_eq!(parsed.scheduler, SchedulerPolicy::Fair);
        assert_eq!(parsed.max_concurrent_jobs, 4);
        assert!(!parsed.strict_analyze);
        // And the knob itself round-trips.
        let strict = RunConfig { strict_analyze: true, ..Default::default() };
        assert!(RunConfig::from_json(&strict.to_json()).unwrap().strict_analyze);
    }

    #[test]
    fn store_knobs_roundtrip_and_default_on_old_json() {
        let cfg = RunConfig {
            store_byte_budget: Some(1 << 20),
            store_dir: Some("/tmp/stark-store".into()),
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.store_byte_budget, Some(1 << 20));
        assert_eq!(back.store_dir.as_deref(), Some("/tmp/stark-store"));
        let cc = back.cluster_config();
        assert_eq!(cc.store_byte_budget, Some(1 << 20));
        assert_eq!(cc.store_dir.as_deref(), Some("/tmp/stark-store"));
        // Pre-store recorded configs keep parsing: unlimited, ephemeral.
        let legacy = r#"{"n":64,"b":2,"algo":"stark","backend":"packed",
            "executors":2,"cores_per_executor":2,"seed":1}"#;
        let parsed = RunConfig::from_json(legacy).unwrap();
        assert_eq!(parsed.store_byte_budget, None);
        assert_eq!(parsed.store_dir, None);
    }

    #[test]
    fn chaos_and_bandwidth_roundtrip() {
        let cfg = RunConfig {
            net_bandwidth: Some(1e9),
            chaos: Some(ChaosConfig {
                seed: 7,
                fail_rate: 0.1,
                panic_rate: 0.05,
                slow_rate: 0.2,
                slow_factor: 3.0,
                executor_loss_rate: 0.01,
                stage_contains: Some("gbk".into()),
                fail_once_partition: None,
            }),
            max_task_attempts: 6,
            speculation_multiplier: Some(2.5),
            fused_leaf: true,
            ..Default::default()
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.net_bandwidth, Some(1e9));
        assert_eq!(back.chaos, cfg.chaos);
        assert_eq!(back.max_task_attempts, 6);
        assert_eq!(back.speculation_multiplier, Some(2.5));
        assert!(back.fused_leaf);
    }

    #[test]
    fn legacy_failure_object_parses_as_fail_once_chaos() {
        let legacy = r#"{"n":64,"b":2,"algo":"stark","backend":"packed",
            "executors":2,"cores_per_executor":2,"seed":1,
            "failure":{"stage_contains":"gbk","partition":3}}"#;
        let parsed = RunConfig::from_json(legacy).unwrap();
        assert_eq!(parsed.chaos, Some(ChaosConfig::fail_once("gbk", 3)));
        assert_eq!(parsed.max_task_attempts, 4, "legacy configs keep the default budget");
    }

    #[test]
    fn auto_algo_and_splits_roundtrip() {
        let cfg = RunConfig { algo: Algorithm::Auto, splits: Splits::Auto, ..Default::default() };
        let json = cfg.to_json();
        assert!(json.contains("\"algo\":\"auto\""), "{json}");
        assert!(json.contains("\"b\":\"auto\""), "{json}");
        let back = RunConfig::from_json(&json).unwrap();
        assert_eq!(back.algo, Algorithm::Auto);
        assert_eq!(back.splits, Splits::Auto);
        // Fixed splits keep serializing as a plain number (compat).
        let fixed = RunConfig::default().to_json();
        assert!(fixed.contains("\"b\":4"), "{fixed}");
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!("xla".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert_eq!("XLA-PALLAS".parse::<BackendKind>().unwrap(), BackendKind::XlaPallas);
        assert_eq!("naive".parse::<BackendKind>().unwrap(), BackendKind::Naive);
        assert_eq!("blocked".parse::<BackendKind>().unwrap(), BackendKind::Blocked);
        assert_eq!("packed".parse::<BackendKind>().unwrap(), BackendKind::Packed);
        // Back-compat alias for recorded configs.
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Packed);
        assert!("bogus".parse::<BackendKind>().is_err());
    }

    #[test]
    fn cluster_config_propagates() {
        let cfg = RunConfig { executors: 3, cores_per_executor: 5, ..Default::default() };
        assert_eq!(cfg.cluster_config().total_cores(), 15);
    }

    #[test]
    fn native_backends_build() {
        for (kind, name) in [
            (BackendKind::Naive, "naive"),
            (BackendKind::Blocked, "blocked"),
            (BackendKind::Packed, "packed"),
        ] {
            let be = build_backend(kind, 1).unwrap();
            assert_eq!(be.name(), name);
        }
    }
}
