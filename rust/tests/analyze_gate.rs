//! Tier-1: the static analyzer (DESIGN.md S19) as a gate.
//!
//! Positive direction: every plan the repo actually ships — stark,
//! marlin and mllib at b ∈ {2, 4, 8} plus the acceptance expression
//! `(A·B+C)·Dᵀ` — must analyze CLEAN, because the debug-build hooks in
//! `DistExpr::collect` and serve's submit path reject any plan with an
//! error-severity finding (so a regression here would also break every
//! other tier-1 test that collects an expression).
//!
//! Negative direction: real engine pipelines with seeded violations
//! must produce exactly the pinned `STARK-Axxx` code (the hand-built
//! lineage/tag negatives live in `src/analyze/mod.rs` unit tests).

use std::sync::Arc;

use stark::algos::Algorithm;
use stark::analyze;
use stark::api::StarkSession;
use stark::config::BackendKind;
use stark::cost::Splits;
use stark::engine::{ClusterConfig, HashPartitioner, SparkContext};
use stark::matrix::DenseMatrix;

fn session() -> StarkSession {
    StarkSession::builder()
        .cluster(ClusterConfig::new(2, 2))
        .backend_kind(BackendKind::Packed)
        .build()
        .expect("test session")
}

#[test]
fn shipped_plans_analyze_clean() {
    let s = session();
    for algo in [Algorithm::Stark, Algorithm::Marlin, Algorithm::Mllib] {
        for b in [2usize, 4, 8] {
            let plan = s.plan_for(algo, Splits::Fixed(b), 64 * b).expect("plan");
            let diags = analyze::analyze_node_plan("", &plan);
            assert!(diags.is_empty(), "{algo} b={b}: {}", analyze::render(&diags));
        }
    }
}

#[test]
fn acceptance_expression_analyzes_clean_and_collects() {
    let s = session();
    let a = s.matrix(&DenseMatrix::random(32, 32, 21));
    let b = s.matrix(&DenseMatrix::random(32, 32, 22));
    let c = s.matrix(&DenseMatrix::random(32, 32, 23));
    let d = s.matrix(&DenseMatrix::random(32, 32, 24));
    let e = a.multiply(&b).add(&c).multiply(&d.transpose());
    let plan = e.plan().expect("plan");
    assert_eq!(plan.expression, "(A·B+C)·Dᵀ");
    let diags = analyze::analyze_plan(&plan);
    assert!(diags.is_empty(), "{}", analyze::render(&diags));
    // Debug builds run the analyzer inside collect(); success here means
    // the real submit-time gate passed too.
    e.collect().expect("acceptance expression must clear the analyze gate");
}

#[test]
fn engine_lineage_of_a_well_labeled_fold_is_clean() {
    let ctx = SparkContext::new(ClusterConfig::new(2, 1));
    let folded = ctx
        .parallelize((0u64..32).map(|i| (i % 4, i)).collect(), 4)
        .fold_by_key_with("sum", Arc::new(HashPartitioner::new(2)), |v| v, |a, v| a + v, |a, b| {
            a + b
        });
    let diags = analyze::analyze_lineage(folded.lineage());
    assert!(diags.is_empty(), "{}", analyze::render(&diags));
}

#[test]
fn engine_fold_mislabeled_as_divide_stage_is_a003() {
    // A grouping shuffle that claims to be a divide stage but routes by
    // plain key hash: the analyzer must flag it (warning severity — it
    // is a performance defect, not a correctness one, so it reports
    // without rejecting).
    let ctx = SparkContext::new(ClusterConfig::new(2, 1));
    let folded = ctx
        .parallelize((0u64..32).map(|i| (i % 4, i)).collect(), 4)
        .fold_by_key_with(
            "divide/L0",
            Arc::new(HashPartitioner::new(2)),
            |v| v,
            |a, v| a + v,
            |a, b| a + b,
        );
    let diags = analyze::analyze_lineage(folded.lineage());
    assert_eq!(diags.len(), 1, "{}", analyze::render(&diags));
    assert_eq!(diags[0].code, analyze::MISALIGNED_PARTITIONER);
    assert_eq!(diags[0].severity, stark::Severity::Warning);
    assert!(!analyze::has_errors(&diags));
}
