//! Integration: the session/DistMatrix public API and the cost-model
//! planner — auto-planned multiplies across arbitrary shapes, handle
//! caching across jobs, and the planner's crossover surfaced end to end.

use stark::algos::Algorithm;
use stark::api::StarkSession;
use stark::cost::{Calibration, Splits};
use stark::engine::ClusterConfig;
use stark::matrix::multiply::matmul_naive;
use stark::matrix::DenseMatrix;
use stark::util::prop::{assert_prop, Draw};
use stark::StarkError;

fn session() -> StarkSession {
    StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap()
}

/// Auto-planned multiplies over random odd/rectangular shapes: the
/// padded product, cropped back, must match the dense reference, and
/// re-running the identical request must reproduce the result bit for
/// bit (distributed execution is deterministic).
#[test]
fn prop_auto_planned_multiplies_match_dense_reference() {
    let s = session();
    assert_prop("auto plan odd shapes", 0xA9_1, 25, |rng| {
        let m = rng.range(1, 41);
        let k = rng.range(1, 41);
        let n = rng.range(1, 41);
        let a = DenseMatrix::random(m, k, rng.next_u64());
        let b = DenseMatrix::random(k, n, rng.next_u64());
        let (ha, hb) = (s.matrix(&a), s.matrix(&b));
        let out = ha
            .multiply(&hb)
            .collect()
            .map_err(|e| format!("{m}x{k}@{k}x{n}: {e}"))?;
        if (out.c.rows(), out.c.cols()) != (m, n) {
            return Err(format!(
                "shape: got {}x{}, want {m}x{n}",
                out.c.rows(),
                out.c.cols()
            ));
        }
        if out.plan.algorithm == Algorithm::Auto {
            return Err("plan left Auto unresolved".to_string());
        }
        let want = matmul_naive(&a, &b);
        let diff = want.max_abs_diff(&out.c);
        if diff > 1e-9 {
            return Err(format!(
                "{m}x{k}@{k}x{n} via {} b={}: diff {diff}",
                out.plan.algorithm, out.plan.b
            ));
        }
        // Determinism: the same auto-planned request is bit-stable.
        let again = ha.multiply(&hb).collect().map_err(|e| e.to_string())?;
        if again.c.as_slice() != out.c.as_slice() {
            return Err("auto-planned rerun changed bits".to_string());
        }
        Ok(())
    });
}

/// One A against many Bs: the A handle splits its blocks exactly once
/// however many multiplies consume it (padding included).
#[test]
fn one_a_many_bs_distributes_a_once() {
    let s = session();
    let am = DenseMatrix::random(24, 24, 1); // pads to 32 under auto
    let a = s.matrix(&am);
    for seed in 2..6u64 {
        let bm = DenseMatrix::random(24, 24, seed);
        let out = a.multiply(&s.matrix(&bm)).collect().unwrap();
        assert!(matmul_naive(&am, &bm).allclose(&out.c, 1e-9), "seed {seed}");
    }
    assert_eq!(a.splits_computed(), 1, "A was re-split across jobs");
}

/// The acceptance criterion: Auto provably selects different algorithms
/// and splits on opposite sides of the cost-model crossover. At the
/// default calibration on 4 cores the crossover sits between n=1024 and
/// n=2048 (plan level); in execution the same workload flips from a
/// baseline to Stark when the calibration zeroes the communication term.
#[test]
fn auto_crossover_changes_selection() {
    let s = session(); // 2×2 = 4 cores
    let small = s.plan(1024);
    let large = s.plan(2048);
    assert_ne!(small.algorithm, Algorithm::Stark, "small side: {:?}", small.considered[0]);
    assert_eq!(large.algorithm, Algorithm::Stark, "large side: {:?}", large.considered[0]);
    assert_eq!((s.plan(4096).algorithm, s.plan(4096).b), (Algorithm::Stark, 4));

    // Execution-level flip at a test-sized n (β=0 moves the crossover
    // below 256; see the planner's `calibration_moves_the_crossover`).
    let am = DenseMatrix::random(256, 256, 3);
    let bm = DenseMatrix::random(256, 256, 4);
    let want = matmul_naive(&am, &bm);
    let baseline_side = s.matrix(&am).multiply(&s.matrix(&bm)).collect().unwrap();
    assert_ne!(baseline_side.plan.algorithm, Algorithm::Stark);
    assert!(want.allclose(&baseline_side.c, 1e-9));

    let comp_only = StarkSession::builder()
        .cluster(ClusterConfig::new(2, 2))
        .calibration(Calibration { alpha: 1e-9, beta: 0.0 })
        .build()
        .unwrap();
    let stark_side = comp_only.matrix(&am).multiply(&comp_only.matrix(&bm)).collect().unwrap();
    assert_eq!(stark_side.plan.algorithm, Algorithm::Stark);
    assert!(want.allclose(&stark_side.c, 1e-9));
}

/// Incompatible operands are typed errors at the API boundary — the
/// process no longer aborts on a bad request.
#[test]
fn incompatible_operands_do_not_panic() {
    let s = session();
    let a = s.matrix(&DenseMatrix::random(7, 5, 1));
    let b = s.matrix(&DenseMatrix::random(6, 7, 2)); // 5 != 6
    match a.multiply(&b).collect() {
        Err(StarkError::ShapeMismatch { a: (7, 5), b: (6, 7), .. }) => {}
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
    // Stark with a non-power-of-two fixed b: typed, not fatal.
    let sq = s.matrix(&DenseMatrix::random(12, 12, 3));
    match sq.multiply(&sq).algorithm(Algorithm::Stark).splits(Splits::Fixed(3)).collect() {
        Err(StarkError::InvalidSplits { algorithm: Algorithm::Stark, b: 3, .. }) => {}
        other => panic!("expected InvalidSplits, got {other:?}"),
    }
    // The same b is fine for the baselines (12 % 3 == 0).
    let out =
        sq.multiply(&sq).algorithm(Algorithm::Marlin).splits(Splits::Fixed(3)).collect().unwrap();
    assert_eq!(out.plan.b, 3);
    assert!(matmul_naive(sq.dense(), sq.dense()).allclose(&out.c, 1e-9));
}

/// `Algorithm` round-trips its new `auto` spelling alongside the three
/// concrete systems.
#[test]
fn algorithm_and_splits_parse_auto() {
    assert_eq!("auto".parse::<Algorithm>().unwrap(), Algorithm::Auto);
    assert_eq!(Algorithm::Auto.to_string(), "auto");
    for algo in Algorithm::ALL {
        assert_eq!(algo.to_string().parse::<Algorithm>().unwrap(), algo);
        assert_ne!(algo, Algorithm::Auto, "ALL stays concrete");
    }
    assert_eq!("auto".parse::<Splits>().unwrap(), Splits::Auto);
    assert_eq!("16".parse::<Splits>().unwrap(), Splits::Fixed(16));
}
