//! Integration: the PJRT runtime against the AOT artifacts.
//!
//! These tests need `artifacts/` (built by `make artifacts`); they are
//! skipped with a notice when it is absent so `cargo test` stays green on
//! a fresh checkout.

use std::sync::Arc;

use stark::matrix::{matmul_blocked, DenseMatrix};
use stark::runtime::{
    find_artifacts_dir, ArtifactLibrary, LeafBackend, NativeBackend, XlaBackend, XlaService,
};

fn library() -> Option<ArtifactLibrary> {
    let dir = find_artifacts_dir()?;
    ArtifactLibrary::load(dir).ok()
}

macro_rules! require_artifacts {
    () => {
        match library() {
            Some(lib) => lib,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_contains_expected_families() {
    let lib = require_artifacts!();
    for kind in ["matmul", "strassen_leaf", "add", "sub", "mterms", "combine7"] {
        assert!(
            lib.manifest().artifacts.iter().any(|e| e.kind == kind),
            "missing artifact kind {kind}"
        );
    }
    let blocks = lib.blocks_for("matmul", "dot", "f64");
    assert!(blocks.contains(&64) && blocks.contains(&128), "blocks: {blocks:?}");
    // pallas and dot cover the same block grid.
    assert_eq!(blocks, lib.blocks_for("matmul", "pallas", "f64"));
}

#[test]
fn xla_matmul_matches_native_across_blocks() {
    let lib = require_artifacts!();
    let svc = XlaService::new(lib.clone(), 1, "dot").unwrap();
    for &n in lib.blocks_for("matmul", "dot", "f64").iter().filter(|&&n| n <= 256) {
        let a = DenseMatrix::random(n, n, n as u64);
        let b = DenseMatrix::random(n, n, n as u64 + 1);
        let got = svc.matmul(a.clone(), b.clone()).unwrap();
        let want = matmul_blocked(&a, &b);
        assert!(
            want.allclose(&got, 1e-10),
            "xla dot matmul_{n} diverges: {}",
            want.max_abs_diff(&got)
        );
    }
}

#[test]
fn pallas_artifacts_match_dot_artifacts() {
    // The L1 Pallas kernel (interpret-lowered) and the plain HLO dot must
    // compute the same product — the cross-implementation oracle check,
    // now on the Rust side of the AOT boundary.
    let lib = require_artifacts!();
    let dot = XlaService::new(lib.clone(), 1, "dot").unwrap();
    let pallas = XlaService::new(lib.clone(), 1, "pallas").unwrap();
    for n in [16usize, 64] {
        let a = DenseMatrix::random(n, n, 100 + n as u64);
        let b = DenseMatrix::random(n, n, 200 + n as u64);
        let d = dot.matmul(a.clone(), b.clone()).unwrap();
        let p = pallas.matmul(a, b).unwrap();
        assert!(d.allclose(&p, 1e-10), "pallas vs dot at n={n}: {}", d.max_abs_diff(&p));
    }
}

#[test]
fn strassen_leaf_artifact_matches_composed() {
    let lib = require_artifacts!();
    let svc = XlaService::new(lib, 1, "dot").unwrap();
    let n = 64;
    let a = DenseMatrix::random(2 * n, 2 * n, 31);
    let b = DenseMatrix::random(2 * n, 2 * n, 32);
    let quads = [
        a.submatrix(0, 0, n, n),
        a.submatrix(0, n, n, n),
        a.submatrix(n, 0, n, n),
        a.submatrix(n, n, n, n),
        b.submatrix(0, 0, n, n),
        b.submatrix(0, n, n, n),
        b.submatrix(n, 0, n, n),
        b.submatrix(n, n, n, n),
    ];
    let [c11, c12, c21, c22] = svc.strassen_leaf(quads).unwrap();
    let want = matmul_blocked(&a, &b);
    assert!(want.submatrix(0, 0, n, n).allclose(&c11, 1e-9));
    assert!(want.submatrix(0, n, n, n).allclose(&c12, 1e-9));
    assert!(want.submatrix(n, 0, n, n).allclose(&c21, 1e-9));
    assert!(want.submatrix(n, n, n, n).allclose(&c22, 1e-9));
}

#[test]
fn backend_falls_back_on_unknown_block_size() {
    let lib = require_artifacts!();
    let svc = Arc::new(XlaService::new(lib, 1, "dot").unwrap());
    // cutover 0: always dispatch to XLA so the fallback path is exercised.
    let be = XlaBackend::with_cutover(svc, 0);
    // 24 is not in the power-of-two artifact grid -> native fallback.
    let a = DenseMatrix::random(24, 24, 41);
    let b = DenseMatrix::random(24, 24, 42);
    let got = be.multiply(&a, &b);
    assert!(matmul_blocked(&a, &b).allclose(&got, 1e-10));
    assert_eq!(be.fallbacks(), 1);
    // A supported size does not bump the counter.
    let a = DenseMatrix::random(64, 64, 43);
    let b = DenseMatrix::random(64, 64, 44);
    be.multiply(&a, &b);
    assert_eq!(be.fallbacks(), 1);
}

#[test]
fn service_is_safe_under_concurrency() {
    let lib = require_artifacts!();
    let svc = Arc::new(XlaService::new(lib, 2, "dot").unwrap());
    svc.warmup(32).unwrap();
    let native = NativeBackend::default();
    std::thread::scope(|scope| {
        for t in 0..8 {
            let svc = svc.clone();
            let native = &native;
            scope.spawn(move || {
                for i in 0..5 {
                    let a = DenseMatrix::random(32, 32, (t * 100 + i) as u64);
                    let b = DenseMatrix::random(32, 32, (t * 100 + i + 50) as u64);
                    let got = svc.matmul(a.clone(), b.clone()).unwrap();
                    let want = native.multiply(&a, &b);
                    assert!(want.allclose(&got, 1e-10));
                }
            });
        }
    });
}

#[test]
fn rejects_unknown_impl_family() {
    let lib = require_artifacts!();
    assert!(XlaService::new(lib, 1, "bogus").is_err());
}

#[test]
fn find_artifacts_dir_honors_env_override() {
    // Invalid override is ignored (falls through to the walk-up search).
    std::env::set_var("STARK_ARTIFACTS", "/definitely/not/here");
    let found = find_artifacts_dir();
    std::env::remove_var("STARK_ARTIFACTS");
    // With the override invalid, we still find the repo artifacts when
    // they exist; the assertion is that this never panics and that any
    // result actually contains a manifest.
    if let Some(dir) = found {
        assert!(dir.join("manifest.json").exists());
    }
}
