//! Concurrency: many clients against one serve job queue, and many jobs
//! against one engine context. The invariants under test are the
//! scheduler PR's acceptance criteria: concurrent jobs complete with
//! bit-correct products, and every response carries only its own job's
//! stage metrics (no cross-job bleed through a shared "current" slot).

use stark::algos::{self, Algorithm, StarkConfig};
use stark::api::StarkSession;
use stark::config::{build_backend, BackendKind};
use stark::cost::Splits;
use stark::engine::{ClusterConfig, SparkContext};
use stark::matrix::multiply::matmul_naive;
use stark::matrix::DenseMatrix;
use stark::serve::{request, Server, ServerState};
use stark::util::json::Value;

fn to_json(m: &DenseMatrix) -> Value {
    Value::Array(
        (0..m.rows())
            .map(|r| Value::Array((0..m.cols()).map(|c| Value::num(m.get(r, c))).collect()))
            .collect(),
    )
}

/// One client workload: algorithm, split, seeded 8×8 inputs.
fn workload(client: usize, i: usize) -> (Algorithm, usize, DenseMatrix, DenseMatrix) {
    let algo = [Algorithm::Stark, Algorithm::Marlin, Algorithm::Mllib][(client + i) % 3];
    let b = [2usize, 4][(client * 7 + i) % 2];
    let seed = 1000 + (client * 100 + i) as u64;
    let a = DenseMatrix::random(8, 8, seed);
    let bm = DenseMatrix::random(8, 8, seed + 1);
    (algo, b, a, bm)
}

/// The reference for bit-correctness: the same distributed run on a
/// private context. Distributed execution is deterministic (pure
/// closures, deterministic partitioners, outputs sorted by partition),
/// so the served product must match BIT FOR BIT — any deviation under
/// concurrency means jobs corrupted each other.
fn local_reference(
    algo: Algorithm,
    b: usize,
    a: &DenseMatrix,
    bm: &DenseMatrix,
) -> (DenseMatrix, Vec<String>) {
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let backend = build_backend(BackendKind::Packed, 1).unwrap();
    let out = algos::multiply_general(algo, &ctx, backend, a, bm, b, &StarkConfig::default())
        .unwrap();
    let labels = out.job.stages.iter().map(|s| s.label.clone()).collect();
    (out.c, labels)
}

#[test]
fn serve_concurrent_clients_bit_correct_and_isolated() {
    const CLIENTS: usize = 4;
    const REQUESTS: usize = 3;

    let session = StarkSession::builder()
        .cluster(ClusterConfig::new(2, 2))
        .backend(build_backend(BackendKind::Packed, 2).unwrap())
        .build()
        .unwrap();
    let state = ServerState {
        session,
        default_splits: Splits::Fixed(2),
        max_inflight_jobs: 16,
        job_runners: 3,
    };
    let mut server = Server::start("127.0.0.1:0", state).unwrap();
    let addr = server.addr().to_string();

    let mut handles = Vec::new();
    for client in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..REQUESTS {
                let (algo, b, a, bm) = workload(client, i);
                let base = vec![
                    ("algo", Value::str(algo.to_string())),
                    ("b", Value::num(b as f64)),
                    ("a", to_json(&a)),
                    ("b_mat", to_json(&bm)),
                    ("return_c", Value::Bool(true)),
                ];
                // Mixed request styles: even rounds use the synchronous
                // sugar, odd rounds drive submit + wait explicitly.
                let resp = if i % 2 == 0 {
                    let mut fields = vec![("op", Value::str("multiply"))];
                    fields.extend(base);
                    request(&addr, &Value::obj(fields)).unwrap()
                } else {
                    let mut fields = vec![("op", Value::str("submit"))];
                    fields.extend(base);
                    let submitted = request(&addr, &Value::obj(fields)).unwrap();
                    assert_eq!(
                        submitted.get("ok"),
                        Some(&Value::Bool(true)),
                        "client {client} req {i}: {submitted:?}"
                    );
                    let id = submitted.get("job_id").unwrap().as_u64().unwrap();
                    request(
                        &addr,
                        &Value::obj(vec![
                            ("op", Value::str("wait")),
                            ("job_id", Value::num(id as f64)),
                            ("timeout_ms", Value::num(120_000.0)),
                        ]),
                    )
                    .unwrap()
                };
                assert_eq!(
                    resp.get("ok"),
                    Some(&Value::Bool(true)),
                    "client {client} req {i} ({algo} b={b}): {resp:?}"
                );

                let (want_c, want_labels) = local_reference(algo, b, &a, &bm);
                // Bit-correct product: the JSON number writer emits
                // shortest-roundtrip f64, so equality here is exact.
                let rows = resp.get("c").unwrap().as_array().unwrap();
                for (r, rowv) in rows.iter().enumerate() {
                    for (c, x) in rowv.as_array().unwrap().iter().enumerate() {
                        let got = x.as_f64().unwrap();
                        assert!(
                            want_c.get(r, c) == got,
                            "client {client} req {i} ({algo} b={b}) bit mismatch at \
                             ({r},{c}): {} vs {got}",
                            want_c.get(r, c)
                        );
                    }
                }
                // Per-job metric isolation: exactly the stage sequence
                // this algorithm produces when run alone — nothing
                // missing, nothing leaked in from concurrent jobs.
                let got_labels: Vec<String> = resp
                    .get("stages")
                    .unwrap()
                    .as_array()
                    .unwrap()
                    .iter()
                    .map(|s| s.get("label").unwrap().as_str().unwrap().to_string())
                    .collect();
                assert_eq!(
                    got_labels, want_labels,
                    "client {client} req {i} ({algo} b={b}): stage set differs from \
                     a solo run"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}

/// Regression: `wait` on a job that fails mid-retry must return the
/// typed failure the moment the job dies — not poll until `timeout_ms`
/// expires. The session injects certain task errors so the job exhausts
/// its (small) retry budget almost instantly; the 120 s wait budget
/// exists only to make any poll-to-deadline regression unmissable.
#[test]
fn wait_returns_typed_failure_immediately_not_at_timeout() {
    let mut cc = ClusterConfig::new(2, 2);
    cc.chaos = Some(stark::engine::ChaosConfig { fail_rate: 1.0, ..Default::default() });
    cc.max_task_attempts = 3;
    let session = StarkSession::builder()
        .cluster(cc)
        .backend(build_backend(BackendKind::Packed, 2).unwrap())
        .build()
        .unwrap();
    let state = ServerState {
        session,
        default_splits: Splits::Fixed(2),
        max_inflight_jobs: 4,
        job_runners: 1,
    };
    let mut server = Server::start("127.0.0.1:0", state).unwrap();
    let addr = server.addr().to_string();

    let submitted = request(
        &addr,
        &Value::obj(vec![
            ("op", Value::str("submit")),
            ("algo", Value::str("stark")),
            ("n", Value::num(32.0)),
            ("b", Value::num(2.0)),
            ("seed", Value::num(5.0)),
        ]),
    )
    .unwrap();
    assert_eq!(submitted.get("ok"), Some(&Value::Bool(true)), "{submitted:?}");
    let id = submitted.get("job_id").unwrap().as_u64().unwrap();

    let started = std::time::Instant::now();
    let resp = request(
        &addr,
        &Value::obj(vec![
            ("op", Value::str("wait")),
            ("job_id", Value::num(id as f64)),
            ("timeout_ms", Value::num(120_000.0)),
        ]),
    )
    .unwrap();
    let waited = started.elapsed();
    assert_eq!(resp.get("ok"), Some(&Value::Bool(false)), "doomed job succeeded: {resp:?}");
    let err = resp.get("error").and_then(Value::as_str).unwrap_or_default();
    assert!(err.contains("task failed"), "expected the typed TaskFailed text: {resp:?}");
    assert!(
        waited < std::time::Duration::from_secs(60),
        "wait polled toward its timeout instead of returning the failure: {waited:?}"
    );
    server.stop();
}

#[test]
fn engine_concurrent_multiplies_on_shared_context() {
    // The acceptance criterion at engine level: concurrent `run_job`
    // scopes on ONE SparkContext (one worker pool, fair scheduler) both
    // complete correctly, and each JobMetrics holds exactly its own
    // stage count — eq. (25) for Stark.
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let backend = build_backend(BackendKind::Packed, 2).unwrap();
    let mut handles = Vec::new();
    for (t, b) in [2usize, 4, 8].into_iter().enumerate() {
        let ctx = ctx.clone();
        let backend = backend.clone();
        handles.push(std::thread::spawn(move || {
            let a = DenseMatrix::random(16, 16, 70 + t as u64);
            let bm = DenseMatrix::random(16, 16, 80 + t as u64);
            let out = algos::stark::multiply(&ctx, backend, &a, &bm, b, &StarkConfig::default())
                .unwrap();
            let want = matmul_naive(&a, &bm);
            assert!(
                want.allclose(&out.c, 1e-9),
                "b={b}: concurrent product diverged from reference"
            );
            assert_eq!(
                out.job.stages.len(),
                algos::stark::predicted_stages(b),
                "b={b}: stage metrics leaked across concurrent jobs: {:?}",
                out.job.stages.iter().map(|s| s.label.clone()).collect::<Vec<_>>()
            );
            out.job.id
        }));
    }
    let ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Distinct job ids, all archived.
    assert_eq!(ids.iter().collect::<std::collections::HashSet<_>>().len(), 3);
    assert_eq!(ctx.metrics().jobs().len(), 3);
}
