//! Chaos soak suite (DESIGN.md S20): seeded fault injection across every
//! algorithm and the acceptance expression. The contract under test is
//! lineage-backed recovery — whatever chaos does (task errors, panics,
//! slow stragglers, whole-executor loss), a job either completes with a
//! product **bit-identical** to its chaos-free run, or fails with a
//! typed error (`TaskFailed`, `JobTimedOut`). Never a wrong answer.

use std::sync::Arc;

use stark::algos::stark::predicted_stages;
use stark::algos::{
    cannon, marlin, mllib, stark as stark_algo, Algorithm, BaselineOptions, StarkConfig,
};
use stark::api::StarkSession;
use stark::cost::Splits;
use stark::engine::{ChaosConfig, ClusterConfig, SparkContext};
use stark::matrix::DenseMatrix;
use stark::runtime::NativeBackend;
use stark::util::prop::{assert_prop, Draw};
use stark::StarkError;

const BASE: BaselineOptions = BaselineOptions { isolate_multiply: false };

fn chaos_cluster(chaos: ChaosConfig) -> ClusterConfig {
    let mut cc = ClusterConfig::new(2, 2);
    cc.chaos = Some(chaos);
    // Generous retry budget: at the 20% soak ceiling a task still fails
    // twelve straight attempts with probability ~4e-9, so the soak pins
    // recovery, not retry exhaustion (which has its own test below).
    cc.max_task_attempts = 12;
    cc
}

fn inputs(n: usize, seed: u64) -> (DenseMatrix, DenseMatrix) {
    (DenseMatrix::random(n, n, seed), DenseMatrix::random(n, n, seed + 1))
}

/// Seeded soak: random chaos mode and rates up to 20%, all four
/// algorithms, every run bit-identical to the chaos-free baseline and
/// with recovery visible in the attempts ledger whenever it fired.
#[test]
fn seeded_chaos_soak_is_bit_identical_for_all_algorithms() {
    let n = 32;
    let b = 4;
    let (a, bm) = inputs(n, 0x50AC);
    let backend = Arc::new(NativeBackend::default());

    let clean_ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let clean_stark =
        stark_algo::multiply(&clean_ctx, backend.clone(), &a, &bm, b, &StarkConfig::default())
            .unwrap();
    let clean_marlin = marlin::multiply(&clean_ctx, backend.clone(), &a, &bm, b, &BASE).unwrap();
    let clean_mllib = mllib::multiply(&clean_ctx, backend.clone(), &a, &bm, b, &BASE).unwrap();
    // Cannon at b = 2: its b² gang must fit the 4-core soak cluster.
    let clean_cannon = cannon::multiply(&clean_ctx, backend.clone(), &a, &bm, 2).unwrap();

    assert_prop("chaos-soak", 0xC4A0_55ED, 8, |rng| {
        let mode = rng.range(0, 5);
        let rate = 0.02 + rng.next_f64() * 0.18; // (0.02, 0.20]
        let chaos = ChaosConfig {
            seed: rng.next_u64(),
            fail_rate: if mode == 0 || mode == 4 { rate } else { 0.0 },
            panic_rate: if mode == 1 || mode == 4 { rate * 0.5 } else { 0.0 },
            slow_rate: if mode == 2 || mode == 4 { rate } else { 0.0 },
            slow_factor: 8.0,
            executor_loss_rate: if mode == 3 || mode == 4 { rate } else { 0.0 },
            stage_contains: None,
            fail_once_partition: None,
        };
        let ctx = SparkContext::new(chaos_cluster(chaos.clone()));
        let s = stark_algo::multiply(&ctx, backend.clone(), &a, &bm, b, &StarkConfig::default())
            .map_err(|e| format!("stark under chaos mode {mode}: {e}"))?;
        let m = marlin::multiply(&ctx, backend.clone(), &a, &bm, b, &BASE)
            .map_err(|e| format!("marlin under chaos mode {mode}: {e}"))?;
        let l = mllib::multiply(&ctx, backend.clone(), &a, &bm, b, &BASE)
            .map_err(|e| format!("mllib under chaos mode {mode}: {e}"))?;
        // Gang failures compound — one bad member discards the whole
        // wave, so P(wave fails) = 1 − (1 − r)^p ≈ 0.59 at the 20%
        // ceiling with p = 4. A 40-wave budget keeps the residual
        // exhaustion probability ≈ 1e-9, matching the per-task budget.
        let mut cannon_cc = chaos_cluster(chaos);
        cannon_cc.max_task_attempts = 40;
        let ctx_cannon = SparkContext::new(cannon_cc);
        let k = cannon::multiply(&ctx_cannon, backend.clone(), &a, &bm, 2)
            .map_err(|e| format!("cannon under chaos mode {mode}: {e}"))?;
        for (name, got, clean) in [
            ("stark", &s, &clean_stark),
            ("marlin", &m, &clean_marlin),
            ("mllib", &l, &clean_mllib),
            ("cannon", &k, &clean_cannon),
        ] {
            if got.c.as_slice() != clean.c.as_slice() {
                return Err(format!("{name} not bit-identical under chaos mode {mode}"));
            }
            // The attempts ledger never hides work: every retry,
            // recompute, and speculative duplicate shows up here.
            let floor = got.job.total_tasks()
                + got.job.total_recomputed_partitions()
                + got.job.total_speculative_wins();
            if got.job.total_attempts() < floor {
                return Err(format!(
                    "{name}: attempts {} below observable work {floor}",
                    got.job.total_attempts()
                ));
            }
        }
        Ok(())
    });
}

/// Barrier semantics under failure: one task failing mid-superstep
/// discards and re-runs the WHOLE gang wave (lock-step supersteps have
/// no per-member retry), visible as p extra attempts on the hit stage —
/// and the recovered product is still bit-identical.
#[test]
fn barrier_failure_recomputes_the_whole_gang_not_one_task() {
    let (a, bm) = inputs(16, 0x6A26);
    let backend = Arc::new(NativeBackend::default());
    let p: u32 = 4; // b = 2 → 2×2 gang

    let clean_ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let clean = cannon::multiply(&clean_ctx, backend.clone(), &a, &bm, 2).unwrap();

    let mut cc = ClusterConfig::new(2, 2);
    cc.chaos = Some(ChaosConfig::fail_once("superstep/1", 1));
    let ctx = SparkContext::new(cc);
    let out = cannon::multiply(&ctx, backend, &a, &bm, 2).unwrap();

    assert_eq!(clean.c.as_slice(), out.c.as_slice(), "gang restart changed the product");
    let hit = out
        .job
        .stages
        .iter()
        .find(|s| s.label.contains("superstep/1"))
        .expect("superstep 1 ran");
    assert_eq!(hit.attempts, 2 * p, "whole gang re-runs: 2 waves × p members, not p + 1");
    assert_eq!(hit.retries, p, "the entire first wave is discarded work");
    for s in out.job.stages.iter().filter(|s| {
        s.label.contains("superstep/") && !s.label.contains("superstep/1")
    }) {
        assert_eq!(s.attempts, p, "stage {}: untouched supersteps stay one-wave", s.label);
        assert_eq!(s.retries, 0, "stage {}", s.label);
    }
}

/// The PR acceptance expression `(A·B + C)·Dᵀ` — a chained multi-multiply
/// job through the session API — survives mixed chaos bit-identically.
#[test]
fn acceptance_expression_survives_mixed_chaos_bit_identically() {
    let n = 16;
    let b = 2;
    let am = DenseMatrix::random(n, n, 61);
    let bm = DenseMatrix::random(n, n, 62);
    let cm = DenseMatrix::random(n, n, 63);
    let dm = DenseMatrix::random(n, n, 64);

    let run = |cc: ClusterConfig| {
        let s = StarkSession::builder().cluster(cc).build().unwrap();
        let (a, bb) = (s.matrix(&am), s.matrix(&bm));
        let (c, d) = (s.matrix(&cm), s.matrix(&dm));
        a.multiply(&bb)
            .algorithm(Algorithm::Stark)
            .splits(Splits::Fixed(b))
            .add(&c)
            .multiply_with(&d.transpose(), Algorithm::Stark, Splits::Fixed(b))
            .collect()
            .unwrap()
    };

    let clean = run(ClusterConfig::new(2, 2));
    for seed in [0xFEED_u64, 0xBEEF, 0x7A57] {
        let chaotic = run(chaos_cluster(ChaosConfig {
            seed,
            fail_rate: 0.15,
            panic_rate: 0.05,
            slow_rate: 0.10,
            slow_factor: 8.0,
            executor_loss_rate: 0.10,
            stage_contains: None,
            fail_once_partition: None,
        }));
        assert_eq!(
            clean.c.as_slice(),
            chaotic.c.as_slice(),
            "expression not bit-identical under chaos seed {seed:#x}"
        );
        assert!(chaotic.job.total_attempts() >= chaotic.job.total_tasks());
    }
}

/// An immediate deadline cancels cleanly with the typed timeout — no
/// partial result, no panic escaping the API.
#[test]
fn deadline_zero_times_out_with_typed_error() {
    let (a, bm) = inputs(16, 0xDEAD);
    let s = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap();
    let err = s
        .matrix(&a)
        .multiply(&s.matrix(&bm))
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(2))
        .deadline(0)
        .collect()
        .unwrap_err();
    match err {
        StarkError::JobTimedOut { deadline_ms, .. } => assert_eq!(deadline_ms, 0),
        other => panic!("expected JobTimedOut, got {other}"),
    }
}

/// A generous deadline is invisible: same bits as the undeadlined run.
#[test]
fn generous_deadline_does_not_change_results() {
    let (a, bm) = inputs(32, 0xD11E);
    let s = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap();
    let (ha, hb) = (s.matrix(&a), s.matrix(&bm));
    let plain =
        ha.multiply(&hb).algorithm(Algorithm::Stark).splits(Splits::Fixed(4)).collect().unwrap();
    let bounded = ha
        .multiply(&hb)
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(4))
        .deadline(120_000)
        .collect()
        .unwrap();
    assert_eq!(plain.c.as_slice(), bounded.c.as_slice());
}

/// Total injection (fail every attempt) exhausts the bounded retry
/// budget and surfaces as `TaskFailed` carrying the attempt count.
#[test]
fn total_injection_exhausts_retries_with_typed_task_failure() {
    let (a, bm) = inputs(16, 0xFA11);
    let mut cc = ClusterConfig::new(2, 2);
    cc.chaos = Some(ChaosConfig { fail_rate: 1.0, ..Default::default() });
    cc.max_task_attempts = 2;
    let s = StarkSession::builder().cluster(cc).build().unwrap();
    let err = s
        .matrix(&a)
        .multiply(&s.matrix(&bm))
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(2))
        .collect()
        .unwrap_err();
    match err {
        StarkError::TaskFailed { attempts, ref reason, .. } => {
            assert_eq!(attempts, 2, "retry budget was 2 attempts: {err}");
            assert!(reason.contains("chaos"), "reason should name the injection: {reason}");
        }
        ref other => panic!("expected TaskFailed, got {other}"),
    }
}

/// Certain executor loss on every stage: each stage recomputes the lost
/// executor's partitions from lineage, the count is observable, and the
/// product is still bit-identical.
#[test]
fn certain_executor_loss_recomputes_from_lineage() {
    let (a, bm) = inputs(32, 0x105E);
    let backend = Arc::new(NativeBackend::default());
    let clean_ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let clean =
        stark_algo::multiply(&clean_ctx, backend.clone(), &a, &bm, 4, &StarkConfig::default())
            .unwrap();
    let ctx = SparkContext::new(chaos_cluster(ChaosConfig {
        seed: 9,
        executor_loss_rate: 1.0,
        ..Default::default()
    }));
    let out =
        stark_algo::multiply(&ctx, backend, &a, &bm, 4, &StarkConfig::default()).unwrap();
    assert_eq!(clean.c.as_slice(), out.c.as_slice(), "recompute changed the product");
    assert!(
        out.job.total_recomputed_partitions() > 0,
        "no lineage recompute recorded despite certain loss"
    );
    assert_eq!(
        out.job.total_attempts(),
        out.job.total_tasks() + out.job.total_recomputed_partitions(),
        "each recomputed partition is exactly one extra attempt"
    );
}

/// Slow-task injection plus speculation: across a few seeds at least one
/// speculative duplicate beats its 1000×-inflated straggler, and every
/// run stays bit-identical (the duplicate IS the same pure closure).
#[test]
fn speculation_beats_injected_stragglers() {
    let (a, bm) = inputs(32, 0x57A6);
    let backend = Arc::new(NativeBackend::default());
    let clean_ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let clean =
        stark_algo::multiply(&clean_ctx, backend.clone(), &a, &bm, 4, &StarkConfig::default())
            .unwrap();
    let mut wins = 0u64;
    for seed in 0..4u64 {
        let mut cc = chaos_cluster(ChaosConfig {
            seed,
            slow_rate: 0.25,
            slow_factor: 1000.0,
            ..Default::default()
        });
        cc.speculation_multiplier = Some(2.0);
        let ctx = SparkContext::new(cc);
        let out =
            stark_algo::multiply(&ctx, backend.clone(), &a, &bm, 4, &StarkConfig::default())
                .unwrap();
        assert_eq!(clean.c.as_slice(), out.c.as_slice(), "speculation changed bits (seed {seed})");
        wins += out.job.total_speculative_wins();
    }
    assert!(wins >= 1, "no speculative win across 4 seeds of 25% × 1000× stragglers");
}

/// Chaos off: the recovery machinery costs exactly nothing. Counters
/// stay zero, attempts == tasks, and the stage ledger still matches the
/// paper's eq. (25) stage count.
#[test]
fn chaos_off_has_zero_recovery_cost_and_keeps_the_eq25_ledger() {
    let (a, bm) = inputs(32, 0x0FF);
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let out = stark_algo::multiply(
        &ctx,
        Arc::new(NativeBackend::default()),
        &a,
        &bm,
        4,
        &StarkConfig::default(),
    )
    .unwrap();
    assert_eq!(out.job.stages.len(), predicted_stages(4), "eq. (25) ledger drifted");
    for s in &out.job.stages {
        assert_eq!(s.retries, 0, "stage {}: retry on a clean run", s.label);
        assert_eq!(s.attempts, s.tasks as u32, "stage {}: phantom attempts", s.label);
        assert_eq!(s.recomputed_partitions, 0, "stage {}", s.label);
        assert_eq!(s.speculative_wins, 0, "stage {}", s.label);
    }
}

/// Block-recursive inversion and solve under seeded chaos (DESIGN.md
/// S23): every injection mode at rates up to the 20% soak ceiling
/// either completes **bit-identical** to the chaos-free run — the
/// recursion's six per-level multiplies all recover through lineage —
/// or fails with a typed error. Never a wrong or NaN-poisoned inverse.
#[test]
fn inversion_and_solve_survive_chaos_bit_identically() {
    let n = 16;
    let mut am = DenseMatrix::random(n, n, 0x1A7);
    for i in 0..n {
        am.set(i, i, am.get(i, i) + n as f64); // diag-dominant: invertible
    }
    let bm = DenseMatrix::random(n, 2, 0x1A8);

    let clean = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap();
    let clean_inv = clean.matrix(&am).inverse().collect().unwrap();
    let clean_solve = clean.matrix(&am).solve(&clean.matrix(&bm)).collect().unwrap();

    assert_prop("inverse-chaos-soak", 0x1AC5_0AC5, 6, |rng| {
        let mode = rng.range(0, 5);
        let rate = 0.02 + rng.next_f64() * 0.18; // (0.02, 0.20]
        let chaos = ChaosConfig {
            seed: rng.next_u64(),
            fail_rate: if mode == 0 || mode == 4 { rate } else { 0.0 },
            panic_rate: if mode == 1 || mode == 4 { rate * 0.5 } else { 0.0 },
            slow_rate: if mode == 2 || mode == 4 { rate } else { 0.0 },
            slow_factor: 8.0,
            executor_loss_rate: if mode == 3 || mode == 4 { rate } else { 0.0 },
            stage_contains: None,
            fail_once_partition: None,
        };
        let s = StarkSession::builder().cluster(chaos_cluster(chaos)).build().unwrap();
        let inv = s
            .matrix(&am)
            .inverse()
            .collect()
            .map_err(|e| format!("inverse under chaos mode {mode}: {e}"))?;
        if inv.c.as_slice() != clean_inv.c.as_slice() {
            return Err(format!("inverse not bit-identical under chaos mode {mode}"));
        }
        if inv.job.total_attempts() < inv.job.total_tasks() {
            return Err("inverse: attempts ledger below task count".to_string());
        }
        let x = s
            .matrix(&am)
            .solve(&s.matrix(&bm))
            .collect()
            .map_err(|e| format!("solve under chaos mode {mode}: {e}"))?;
        if x.c.as_slice() != clean_solve.c.as_slice() {
            return Err(format!("solve not bit-identical under chaos mode {mode}"));
        }
        Ok(())
    });
}

/// A deadline expiring mid-inversion cancels with the typed timeout —
/// no partial result, no escaped panic — and the session is not
/// wedged: the very next job on it completes and is bit-identical to a
/// fresh-session run.
#[test]
fn deadline_mid_inversion_times_out_typed_without_wedging() {
    let n = 24;
    let mut a = DenseMatrix::random(n, n, 0xD1E);
    for i in 0..n {
        a.set(i, i, a.get(i, i) + n as f64);
    }
    let s = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap();
    match s.matrix(&a).inverse().collect_with(Some(0)).unwrap_err() {
        StarkError::JobTimedOut { deadline_ms, .. } => assert_eq!(deadline_ms, 0),
        other => panic!("expected JobTimedOut mid-inversion, got {other}"),
    }
    let after = s.matrix(&a).inverse().collect().unwrap();
    assert!(after.c.as_slice().iter().all(|x| x.is_finite()));
    let fresh = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap();
    let reference = fresh.matrix(&a).inverse().collect().unwrap();
    assert_eq!(after.c.as_slice(), reference.c.as_slice(), "post-timeout run drifted");
}
