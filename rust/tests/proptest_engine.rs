//! Property-based tests on sparklet engine semantics: every wide
//! transformation agrees with its sequential (BTreeMap) specification on
//! arbitrary key/value distributions, partition counts, and cluster
//! shapes.

use std::collections::BTreeMap;

use stark::engine::{ClusterConfig, SparkContext};
use stark::matrix::Rng64;
use stark::util::prop::{assert_prop, Draw};

fn random_pairs(rng: &mut Rng64, max_len: usize, key_space: u64) -> Vec<(u32, u64)> {
    let len = rng.range(0, max_len + 1);
    (0..len).map(|_| (rng.next_below(key_space) as u32, rng.next_below(1000))).collect()
}

fn random_ctx(rng: &mut Rng64) -> SparkContext {
    SparkContext::new(ClusterConfig::new(rng.range(1, 5), rng.range(1, 4)))
}

#[test]
fn prop_group_by_key_matches_spec() {
    assert_prop("group_by_key spec", 0x6B6B, 40, |rng| {
        let pairs = random_pairs(rng, 200, 10);
        let ctx = random_ctx(rng);
        let parts = rng.range(1, 9);
        let out_parts = rng.range(1, 9);
        let mut got: BTreeMap<u32, Vec<u64>> = ctx
            .parallelize(pairs.clone(), parts)
            .group_by_key("g", out_parts)
            .collect("c")
            .into_iter()
            .collect();
        got.values_mut().for_each(|v| v.sort());
        let mut want: BTreeMap<u32, Vec<u64>> = BTreeMap::new();
        for (k, v) in pairs {
            want.entry(k).or_default().push(v);
        }
        want.values_mut().for_each(|v| v.sort());
        if got == want {
            Ok(())
        } else {
            Err(format!("group mismatch: {got:?} vs {want:?}"))
        }
    });
}

#[test]
fn prop_reduce_by_key_matches_fold() {
    assert_prop("reduce_by_key spec", 0x6B6C, 40, |rng| {
        let pairs = random_pairs(rng, 300, 7);
        let ctx = random_ctx(rng);
        let got: BTreeMap<u32, u64> = ctx
            .parallelize(pairs.clone(), rng.range(1, 7))
            .reduce_by_key("r", rng.range(1, 7), |a, b| a + b)
            .collect("c")
            .into_iter()
            .collect();
        let mut want: BTreeMap<u32, u64> = BTreeMap::new();
        for (k, v) in pairs {
            *want.entry(k).or_default() += v;
        }
        if got == want {
            Ok(())
        } else {
            Err(format!("reduce mismatch: {got:?} vs {want:?}"))
        }
    });
}

#[test]
fn prop_join_matches_nested_loop() {
    assert_prop("join spec", 0x6B6D, 30, |rng| {
        let left = random_pairs(rng, 60, 6);
        let right = random_pairs(rng, 60, 6);
        let ctx = random_ctx(rng);
        let mut got: Vec<(u32, (u64, u64))> = ctx
            .parallelize(left.clone(), rng.range(1, 5))
            .join("j", &ctx.parallelize(right.clone(), rng.range(1, 5)), rng.range(1, 7))
            .collect("c");
        got.sort();
        let mut want = Vec::new();
        for (k, v) in &left {
            for (k2, w) in &right {
                if k == k2 {
                    want.push((*k, (*v, *w)));
                }
            }
        }
        want.sort();
        if got == want {
            Ok(())
        } else {
            Err(format!("join mismatch: {} vs {} pairs", got.len(), want.len()))
        }
    });
}

#[test]
fn prop_cogroup_matches_spec() {
    assert_prop("cogroup spec", 0x6B6E, 30, |rng| {
        let left = random_pairs(rng, 50, 5);
        let right = random_pairs(rng, 50, 5);
        let ctx = random_ctx(rng);
        let mut got: BTreeMap<u32, (Vec<u64>, Vec<u64>)> = ctx
            .parallelize(left.clone(), 3)
            .cogroup("cg", &ctx.parallelize(right.clone(), 2), rng.range(1, 6))
            .collect("c")
            .into_iter()
            .collect();
        got.values_mut().for_each(|(a, b)| {
            a.sort();
            b.sort();
        });
        let mut want: BTreeMap<u32, (Vec<u64>, Vec<u64>)> = BTreeMap::new();
        for (k, v) in left {
            want.entry(k).or_default().0.push(v);
        }
        for (k, w) in right {
            want.entry(k).or_default().1.push(w);
        }
        want.values_mut().for_each(|(a, b)| {
            a.sort();
            b.sort();
        });
        if got == want {
            Ok(())
        } else {
            Err("cogroup mismatch".to_string())
        }
    });
}

#[test]
fn prop_narrow_ops_preserve_multiset() {
    assert_prop("narrow ops", 0x6B6F, 40, |rng| {
        let data: Vec<u64> = (0..rng.range(0, 300)).map(|_| rng.next_below(100)).collect();
        let ctx = random_ctx(rng);
        let d = ctx.parallelize(data.clone(), rng.range(1, 9));
        // map ∘ map == map of composition
        let mut lhs = d.map(|x| x + 1).map(|x| x * 2).collect("l");
        let mut rhs: Vec<u64> = data.iter().map(|x| (x + 1) * 2).collect();
        lhs.sort();
        rhs.sort();
        if lhs != rhs {
            return Err("map composition broken".to_string());
        }
        // filter keeps exactly the matching subset
        let kept = d.filter(|x| x % 3 == 0).count("f");
        let want = data.iter().filter(|x| *x % 3 == 0).count();
        if kept != want {
            return Err(format!("filter {kept} != {want}"));
        }
        // union cardinality
        let u = d.union(&d).count("u");
        if u != 2 * data.len() {
            return Err("union cardinality broken".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_partition_by_is_multiset_preserving_and_routed() {
    use stark::engine::{HashPartitioner, Partitioner};
    assert_prop("partition_by", 0x6B70, 30, |rng| {
        let pairs = random_pairs(rng, 150, 20);
        let ctx = random_ctx(rng);
        let parts = rng.range(1, 10);
        let partitioner = std::sync::Arc::new(HashPartitioner::new(parts));
        let d = ctx.parallelize(pairs.clone(), 4).partition_by("pb", partitioner.clone());
        if d.num_partitions() != parts {
            return Err("wrong partition count".to_string());
        }
        let mut got = d.collect("c");
        let mut want = pairs.clone();
        got.sort();
        want.sort();
        if got != want {
            return Err("multiset changed".to_string());
        }
        // Each partition holds only keys that route to it.
        let flags = d
            .map_partitions(move |records| {
                records.iter().map(|(k, _)| partitioner.partition(k)).collect::<Vec<_>>()
            })
            .collect("routes");
        // All route targets must be in range.
        if flags.iter().any(|&p| p >= parts) {
            return Err("route out of range".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_map_side_combine_never_changes_answer() {
    // reduce_by_key (with combine) == group_by_key + fold (without).
    assert_prop("combine equivalence", 0x6B71, 30, |rng| {
        let pairs = random_pairs(rng, 200, 8);
        let ctx = random_ctx(rng);
        let a: BTreeMap<u32, u64> = ctx
            .parallelize(pairs.clone(), 5)
            .reduce_by_key("rbk", 3, |x, y| x + y)
            .collect("c")
            .into_iter()
            .collect();
        let b: BTreeMap<u32, u64> = ctx
            .parallelize(pairs, 5)
            .group_by_key("gbk", 3)
            .map(|(k, vs)| (k, vs.into_iter().sum::<u64>()))
            .collect("c")
            .into_iter()
            .collect();
        if a == b {
            Ok(())
        } else {
            Err("combine changed the answer".to_string())
        }
    });
}

#[test]
fn prop_stage_count_is_shuffles_plus_actions() {
    assert_prop("stage counting", 0x6B72, 20, |rng| {
        let ctx = random_ctx(rng);
        let job = ctx.run_job("count");
        let wide_ops = rng.range(1, 4);
        let mut d = job.parallelize(random_pairs(rng, 100, 5), 4);
        for i in 0..wide_ops {
            d = d
                .group_by_key(&format!("w{i}"), 3)
                .map(|(k, vs)| (k, vs.into_iter().sum::<u64>()));
        }
        d.collect("final");
        let stages = job.stages().len();
        if stages == wide_ops + 1 {
            Ok(())
        } else {
            Err(format!("{stages} stages for {wide_ops} wide ops"))
        }
    });
}

#[test]
fn prop_interleaved_jobs_record_disjoint_complete_stage_sets() {
    // Two jobs race on ONE shared context, each running a random-depth
    // pipeline under its own `run_job` scope. Whatever the interleaving,
    // each scope must hold exactly its own stages: the full set (every
    // shuffle + the final action), all carrying that job's label prefix.
    assert_prop("interleaved job isolation", 0x6B73, 12, |rng| {
        let ctx = random_ctx(rng);
        let depths = [rng.range(1, 4), rng.range(1, 4)];
        let seeds: Vec<Vec<(u32, u64)>> =
            (0..2).map(|_| random_pairs(rng, 80, 5)).collect();
        let mut handles = Vec::new();
        for (t, (wide_ops, pairs)) in depths.iter().zip(seeds).enumerate() {
            let ctx = ctx.clone();
            let wide_ops = *wide_ops;
            handles.push(std::thread::spawn(move || {
                let job = ctx.run_job(&format!("job{t}"));
                let mut d = job.parallelize(pairs, 3);
                for i in 0..wide_ops {
                    d = d
                        .group_by_key(&format!("j{t}/w{i}"), 3)
                        .map(|(k, vs)| (k, vs.into_iter().sum::<u64>()));
                }
                d.collect(&format!("j{t}/final"));
                job.stages()
            }));
        }
        let recorded: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (t, stages) in recorded.iter().enumerate() {
            if stages.len() != depths[t] + 1 {
                return Err(format!(
                    "job{t}: {} stages for {} wide ops",
                    stages.len(),
                    depths[t]
                ));
            }
            let prefix = format!("j{t}/");
            if let Some(alien) = stages.iter().find(|s| !s.label.starts_with(&prefix)) {
                return Err(format!("job{t} recorded foreign stage {:?}", alien.label));
            }
        }
        Ok(())
    });
}
