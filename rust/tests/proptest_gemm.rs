//! Property tests for the packed register-tiled GEMM and its fused
//! Strassen operand packing (`matrix/gemm.rs`), pitted against
//! `matmul_naive` over rectangular, odd, and non-tile-multiple shapes,
//! all four `±` sign combinations of the fused pack, and the distributed
//! leaf-backend swap (bit-invariance).
//!
//! Uses the in-repo property driver (`stark::util::prop`); failures
//! report a reproducing seed.

use std::sync::Arc;

use stark::algos::{stark as stark_algo, StarkConfig};
use stark::engine::{ClusterConfig, SparkContext};
use stark::matrix::gemm::{
    gemm_fused, gemm_packed, gemm_packed_parallel, materialize, MatRef, KC, MR, NR,
};
use stark::matrix::multiply::{matmul_blocked, matmul_naive, Kernel};
use stark::matrix::{DenseMatrix, Rng64};
use stark::runtime::NativeBackend;
use stark::util::prop::{assert_prop, Draw};

fn rand_mat(rng: &mut Rng64, rows: usize, cols: usize) -> DenseMatrix {
    let seed = rng.next_u64();
    DenseMatrix::random(rows, cols, seed)
}

#[test]
fn prop_packed_matches_naive_bitwise_on_arbitrary_shapes() {
    assert_prop("packed == naive (bitwise)", 0x9E44, 40, |rng| {
        // Rectangular, odd, and tile-straddling shapes alike.
        let m = rng.range(1, 80);
        let k = rng.range(1, 80);
        let n = rng.range(1, 80);
        let a = rand_mat(rng, m, k);
        let b = rand_mat(rng, k, n);
        let want = matmul_naive(&a, &b);
        let got = gemm_packed(&a, &b);
        if want.as_slice() == got.as_slice() {
            Ok(())
        } else {
            Err(format!("{m}x{k}x{n}: diff {}", want.max_abs_diff(&got)))
        }
    });
}

#[test]
fn packed_handles_tile_boundary_shapes() {
    // Deterministic sweep across the micro/macro tile edges, including a
    // contraction dimension that spans two KC blocks.
    for (m, k, n) in [
        (MR - 1, 3, NR - 1),
        (MR, 5, NR),
        (MR + 1, 7, NR + 1),
        (2 * MR + 3, KC + 1, 3 * NR + 2),
        (1, 2 * KC + 5, 1),
        (33, 1, 129),
    ] {
        let a = DenseMatrix::random(m, k, (m * 1000 + k) as u64);
        let b = DenseMatrix::random(k, n, (k * 1000 + n) as u64);
        let want = matmul_naive(&a, &b);
        let got = gemm_packed(&a, &b);
        assert_eq!(want.as_slice(), got.as_slice(), "{m}x{k}x{n}");
    }
}

#[test]
fn prop_fused_all_sign_combinations_match_naive() {
    assert_prop("fused(±,±) == naive over materialized", 0xF0F0, 30, |rng| {
        let m = rng.range(1, 50);
        let k = rng.range(1, 50);
        let n = rng.range(1, 50);
        let (a0, a1) = (rand_mat(rng, m, k), rand_mat(rng, m, k));
        let (b0, b1) = (rand_mat(rng, k, n), rand_mat(rng, k, n));
        let sa = *rng.choice(&[1.0f64, -1.0]);
        let sb = *rng.choice(&[1.0f64, -1.0]);
        let lhs = [(1.0, MatRef::new(&a0)), (sa, MatRef::new(&a1))];
        let rhs = [(1.0, MatRef::new(&b0)), (sb, MatRef::new(&b1))];
        let want = matmul_naive(&materialize(&lhs), &materialize(&rhs));
        let got = gemm_fused(&lhs, &rhs);
        if want.as_slice() == got.as_slice() {
            Ok(())
        } else {
            Err(format!("{m}x{k}x{n} signs ({sa},{sb}): diff {}", want.max_abs_diff(&got)))
        }
    });
}

#[test]
fn fused_sign_combinations_exhaustive() {
    // All four ± combinations on one fixed odd shape (the prop test
    // samples; this nails the full grid).
    let (m, k, n) = (23, 17, 29);
    let a0 = DenseMatrix::random(m, k, 1);
    let a1 = DenseMatrix::random(m, k, 2);
    let b0 = DenseMatrix::random(k, n, 3);
    let b1 = DenseMatrix::random(k, n, 4);
    for sa in [1.0, -1.0] {
        for sb in [1.0, -1.0] {
            let lhs = [(1.0, MatRef::new(&a0)), (sa, MatRef::new(&a1))];
            let rhs = [(1.0, MatRef::new(&b0)), (sb, MatRef::new(&b1))];
            let want_a = if sa > 0.0 { a0.add(&a1) } else { a0.sub(&a1) };
            let want_b = if sb > 0.0 { b0.add(&b1) } else { b0.sub(&b1) };
            let want = matmul_naive(&want_a, &want_b);
            let got = gemm_fused(&lhs, &rhs);
            assert_eq!(want.as_slice(), got.as_slice(), "signs ({sa},{sb})");
        }
    }
}

#[test]
fn prop_fused_views_match_submatrix_copies() {
    assert_prop("fused views == copied quadrants", 0x5EED, 25, |rng| {
        // Quadrant views of a bigger parent vs explicit submatrix copies.
        let h = rng.range(1, 24);
        let parent_a = rand_mat(rng, 2 * h, 2 * h);
        let parent_b = rand_mat(rng, 2 * h, 2 * h);
        let av = MatRef::new(&parent_a);
        let bv = MatRef::new(&parent_b);
        // (A21 − A11)(B11 + B12) — Strassen's M6.
        let lhs = [(1.0, av.view(h, 0, h, h)), (-1.0, av.view(0, 0, h, h))];
        let rhs = [(1.0, bv.view(0, 0, h, h)), (1.0, bv.view(0, h, h, h))];
        let want = matmul_naive(
            &parent_a.submatrix(h, 0, h, h).sub(&parent_a.submatrix(0, 0, h, h)),
            &parent_b.submatrix(0, 0, h, h).add(&parent_b.submatrix(0, h, h, h)),
        );
        let got = gemm_fused(&lhs, &rhs);
        if want.as_slice() == got.as_slice() {
            Ok(())
        } else {
            Err(format!("h={h}: diff {}", want.max_abs_diff(&got)))
        }
    });
}

#[test]
fn prop_parallel_gemm_matches_serial() {
    assert_prop("parallel packed == serial", 0x7EAD, 20, |rng| {
        let m = rng.range(1, 300);
        let k = rng.range(1, 60);
        let n = rng.range(1, 60);
        let threads = rng.range(1, 9);
        let a = rand_mat(rng, m, k);
        let b = rand_mat(rng, k, n);
        let want = gemm_packed(&a, &b);
        let got = gemm_packed_parallel(&a, &b, threads);
        if want.as_slice() == got.as_slice() {
            Ok(())
        } else {
            Err(format!("{m}x{k}x{n} threads={threads}"))
        }
    });
}

#[test]
fn prop_kernel_ladder_is_bitwise_equal() {
    assert_prop("naive == blocked == packed bitwise", 0xB17, 25, |rng| {
        let m = rng.range(1, 70);
        let k = rng.range(1, 70);
        let n = rng.range(1, 70);
        let a = rand_mat(rng, m, k);
        let b = rand_mat(rng, k, n);
        let naive = matmul_naive(&a, &b);
        let blocked = matmul_blocked(&a, &b);
        let packed = gemm_packed(&a, &b);
        if naive.as_slice() != blocked.as_slice() {
            return Err(format!("{m}x{k}x{n}: blocked diverged"));
        }
        if naive.as_slice() != packed.as_slice() {
            return Err(format!("{m}x{k}x{n}: packed diverged"));
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_stark_bit_unchanged_across_leaf_backends() {
    assert_prop("stark product invariant under kernel swap", 0x57A2, 10, |rng| {
        let n = rng.pow2(8, 32);
        let b = rng.pow2(2, n.min(8));
        let a = rand_mat(rng, n, n);
        let bm = rand_mat(rng, n, n);
        let fused = rng.next_f64() < 0.5;
        let cfg = StarkConfig { fused_leaf: fused, ..Default::default() };
        let run = |kernel: Kernel| {
            let ctx = SparkContext::new(ClusterConfig::new(2, 2));
            stark_algo::multiply(&ctx, Arc::new(NativeBackend::new(kernel)), &a, &bm, b, &cfg)
                .unwrap()
                .c
        };
        let reference = run(Kernel::Naive);
        for kernel in [Kernel::Blocked, Kernel::Packed] {
            if reference.as_slice() != run(kernel).as_slice() {
                return Err(format!("n={n} b={b} fused={fused}: {kernel} moved bits"));
            }
        }
        Ok(())
    });
}
