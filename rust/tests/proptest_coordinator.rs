//! Property-based tests on coordinator invariants (DESIGN.md §7):
//! routing, tag algebra, batching/partitioning, product correctness for
//! arbitrary inputs and split depths, shuffle accounting consistency.
//!
//! Uses the in-repo property driver (`stark::util::prop`); failures
//! report a reproducing seed.

use std::sync::Arc;

use stark::algos::{marlin, mllib, stark as stark_algo, BaselineOptions, StarkConfig};
use stark::engine::{Block, ClusterConfig, Side, SparkContext, Tag};
use stark::matrix::{matmul_blocked, DenseMatrix, Rng64};
use stark::runtime::NativeBackend;
use stark::util::prop::{assert_prop, Draw};

/// Baseline options shared by the marlin/mllib property arms.
const BASE: BaselineOptions = BaselineOptions { isolate_multiply: false };

fn random_matrix(rng: &mut Rng64, n: usize) -> DenseMatrix {
    let seed = rng.next_u64();
    DenseMatrix::random(n, n, seed)
}

#[test]
fn prop_stark_matches_reference_for_arbitrary_inputs() {
    assert_prop("stark == naive", 0xA11CE, 25, |rng| {
        let n = rng.pow2(8, 64);
        let b = rng.pow2(1, n.min(16));
        let a = random_matrix(rng, n);
        let bm = random_matrix(rng, n);
        let ctx = SparkContext::new(ClusterConfig::new(rng.range(1, 4), rng.range(1, 3)));
        let cfg = StarkConfig {
            fused_leaf: rng.next_f64() < 0.5,
            isolate_multiply: rng.next_f64() < 0.5,
            map_side_combine: rng.next_f64() < 0.75,
            ..Default::default()
        };
        let out = stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &bm, b, &cfg)
            .unwrap();
        let want = matmul_blocked(&a, &bm);
        let diff = want.max_abs_diff(&out.c);
        if diff < 1e-8 {
            Ok(())
        } else {
            Err(format!("n={n} b={b}: diff {diff}"))
        }
    });
}

#[test]
fn prop_baselines_match_reference() {
    assert_prop("marlin/mllib == naive", 0xB0B, 20, |rng| {
        let n = rng.pow2(8, 64);
        let divisors: Vec<usize> = (1..=n.min(16)).filter(|d| n % d == 0).collect();
        let b = *rng.choice(&divisors);
        let a = random_matrix(rng, n);
        let bm = random_matrix(rng, n);
        let ctx = SparkContext::new(ClusterConfig::new(2, 2));
        let want = matmul_blocked(&a, &bm);
        let m = marlin::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &bm, b, &BASE).unwrap();
        if want.max_abs_diff(&m.c) > 1e-8 {
            return Err(format!("marlin n={n} b={b}"));
        }
        let l = mllib::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &bm, b, &BASE).unwrap();
        if want.max_abs_diff(&l.c) > 1e-8 {
            return Err(format!("mllib n={n} b={b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_all_three_agree_pairwise() {
    assert_prop("pairwise agreement", 0xCAFE, 15, |rng| {
        let n = rng.pow2(16, 64);
        let b = rng.pow2(2, 8);
        let a = random_matrix(rng, n);
        let bm = random_matrix(rng, n);
        let ctx = SparkContext::new(ClusterConfig::new(2, 2));
        let be = Arc::new(NativeBackend::default());
        let s = stark_algo::multiply(&ctx, be.clone(), &a, &bm, b, &StarkConfig::default()).unwrap();
        let m = marlin::multiply(&ctx, be.clone(), &a, &bm, b, &BASE).unwrap();
        let l = mllib::multiply(&ctx, be, &a, &bm, b, &BASE).unwrap();
        let d1 = s.c.max_abs_diff(&m.c);
        let d2 = m.c.max_abs_diff(&l.c);
        if d1 < 1e-8 && d2 < 1e-8 {
            Ok(())
        } else {
            Err(format!("n={n} b={b}: stark-marlin {d1}, marlin-mllib {d2}"))
        }
    });
}

#[test]
fn prop_tag_child_parent_inverse() {
    assert_prop("tag tree inverse", 0x7A6, 200, |rng| {
        let side = *rng.choice(&[Side::A, Side::B, Side::M]);
        let mut tag = Tag::root(side);
        let depth = rng.range(1, 8);
        let mut path = Vec::new();
        for _ in 0..depth {
            let m = rng.next_below(7);
            path.push(m);
            tag = tag.child(m);
        }
        // Walking parents recovers the path in reverse.
        for want_m in path.iter().rev() {
            let (parent, m) = tag.parent();
            if m != *want_m {
                return Err(format!("expected child {want_m}, got {m}"));
            }
            tag = parent;
        }
        if tag != Tag::root(side) {
            return Err("did not return to root".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_mindex_unique_per_level() {
    assert_prop("mindex uniqueness", 0x51D, 50, |rng| {
        let depth = rng.range(1, 5) as u32;
        let count = 7usize.pow(depth);
        let mut seen = std::collections::HashSet::new();
        // Enumerate all paths of `depth` levels; mindex must be a bijection
        // onto [0, 7^depth).
        fn walk(
            tag: Tag,
            depth: u32,
            seen: &mut std::collections::HashSet<u64>,
        ) -> Result<(), String> {
            if depth == 0 {
                if !seen.insert(tag.mindex) {
                    return Err(format!("duplicate mindex {}", tag.mindex));
                }
                return Ok(());
            }
            for m in 0..7 {
                walk(tag.child(m), depth - 1, seen)?;
            }
            Ok(())
        }
        walk(Tag::root(Side::M), depth, &mut seen)?;
        if seen.len() != count {
            return Err(format!("{} unique mindexes, want {count}", seen.len()));
        }
        if seen.iter().max().copied().unwrap_or(0) != count as u64 - 1 {
            return Err("mindex range is not dense".to_string());
        }
        Ok(())
    });
}

#[test]
fn prop_quadrant_routing_partitions_grid() {
    assert_prop("quadrant routing", 0x961D, 100, |rng| {
        let grid = rng.pow2(2, 32) as u32;
        let half = grid / 2;
        let mut counts = [[0u32; 2]; 2];
        for r in 0..grid {
            for c in 0..grid {
                let blk = Block::new(
                    r,
                    c,
                    Tag::root(Side::A),
                    Arc::new(DenseMatrix::zeros(1, 1)),
                );
                let (qr, qc, rr, cc) = blk.quadrant_of(grid);
                if qr > 1 || qc > 1 || rr >= half || cc >= half {
                    return Err(format!("out of range at ({r},{c})"));
                }
                // Invertible: quadrant offset + local coords reproduce (r, c).
                if qr * half + rr != r || qc * half + cc != c {
                    return Err(format!("not invertible at ({r},{c})"));
                }
                counts[qr as usize][qc as usize] += 1;
            }
        }
        let want = half * half;
        if counts.iter().flatten().any(|&c| c != want) {
            return Err(format!("quadrants not balanced: {counts:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_leaf_call_counts() {
    assert_prop("leaf call law", 0x1EAF, 12, |rng| {
        let n = rng.pow2(16, 64);
        let b = rng.pow2(1, 8);
        let a = random_matrix(rng, n);
        let bm = random_matrix(rng, n);
        let ctx = SparkContext::new(ClusterConfig::new(2, 2));
        let be = Arc::new(NativeBackend::default());
        let s = stark_algo::multiply(&ctx, be.clone(), &a, &bm, b, &StarkConfig::default()).unwrap();
        let m = marlin::multiply(&ctx, be, &a, &bm, b, &BASE).unwrap();
        let levels = (b as f64).log2().round() as u32;
        if s.leaf_calls != 7u64.pow(levels) {
            return Err(format!("stark {} != 7^{levels}", s.leaf_calls));
        }
        if m.leaf_calls != (b * b * b) as u64 {
            return Err(format!("marlin {} != {b}^3", m.leaf_calls));
        }
        Ok(())
    });
}

#[test]
fn prop_shuffle_accounting_scales_with_payload() {
    assert_prop("shuffle accounting", 0xACC7, 10, |rng| {
        let n = rng.pow2(16, 32);
        let b = 2usize;
        let a = random_matrix(rng, n);
        let bm = random_matrix(rng, n);
        let run = |mat_a: &DenseMatrix, mat_b: &DenseMatrix| {
            let ctx = SparkContext::new(ClusterConfig::new(2, 2));
            stark_algo::multiply(
                &ctx,
                Arc::new(NativeBackend::default()),
                mat_a,
                mat_b,
                b,
                &StarkConfig::default(),
            )
            .unwrap()
            .job
            .total_shuffle_bytes()
        };
        let small = run(&a, &bm);
        // Doubling n quadruples every block payload; shuffle bytes must
        // grow by ~4x (tag overhead makes it slightly less).
        let a2 = DenseMatrix::random(2 * n, 2 * n, rng.next_u64());
        let b2 = DenseMatrix::random(2 * n, 2 * n, rng.next_u64());
        let big = run(&a2, &b2);
        let ratio = big as f64 / small as f64;
        if (3.5..=4.5).contains(&ratio) {
            Ok(())
        } else {
            Err(format!("shuffle ratio {ratio} (small={small}, big={big})"))
        }
    });
}

#[test]
fn prop_determinism_same_seed_same_everything() {
    assert_prop("determinism", 0xD7D7, 8, |rng| {
        let n = rng.pow2(16, 64);
        let b = rng.pow2(2, 4);
        let seed = rng.next_u64();
        let run = || {
            let a = DenseMatrix::random(n, n, seed);
            let bm = DenseMatrix::random(n, n, seed + 1);
            let ctx = SparkContext::new(ClusterConfig::new(2, 2));
            let out =
                stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &bm, b, &StarkConfig::default())
                    .unwrap();
            (out.c, out.leaf_calls, out.job.total_shuffle_bytes())
        };
        let (c1, l1, s1) = run();
        let (c2, l2, s2) = run();
        if c1.max_abs_diff(&c2) != 0.0 {
            return Err("results differ bitwise".to_string());
        }
        if l1 != l2 || s1 != s2 {
            return Err(format!("metrics differ: {l1}/{l2} {s1}/{s2}"));
        }
        Ok(())
    });
}
