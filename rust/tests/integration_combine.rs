//! Integration: the map-side signed combining path (fold-by-key) against
//! its sequential specification, the Arc-reuse guarantee for
//! single-positive-operand groups, and a serve-layer round-trip through
//! the converted multiply pipeline.

use std::collections::BTreeMap;
use std::sync::Arc;

use stark::algos::common::{signed_finalize, signed_merge, SignedBlock};
use stark::engine::{ClusterConfig, SparkContext};
use stark::matrix::DenseMatrix;
use stark::util::prop::{assert_prop, Draw};

#[test]
fn prop_fold_by_key_equals_group_then_sum() {
    assert_prop("signed fold == group+sum", 0xF01D, 25, |rng| {
        let keys = rng.range(1, 6) as u32;
        let n = rng.pow2(2, 8);
        let count = rng.range(1, 40);
        let pairs: Vec<(u32, SignedBlock)> = (0..count)
            .map(|_| {
                let k = rng.range(0, keys as usize) as u32;
                let sign = if rng.next_f64() < 0.4 { -1.0 } else { 1.0 };
                let seed = rng.next_u64();
                (k, (sign, Arc::new(DenseMatrix::random(n, n, seed))))
            })
            .collect();
        let ctx = SparkContext::new(ClusterConfig::new(rng.range(1, 4), rng.range(1, 3)));
        let parts = rng.range(1, 7);
        let folded: BTreeMap<u32, DenseMatrix> = ctx
            .parallelize(pairs.clone(), rng.range(1, 6))
            .fold_by_key("fold", parts, |v: SignedBlock| v, signed_merge, signed_merge)
            .collect("c")
            .into_iter()
            .map(|(k, acc)| (k, (*signed_finalize(acc)).clone()))
            .collect();
        // Sequential specification: Σ sign · block per key.
        let mut want: BTreeMap<u32, DenseMatrix> = BTreeMap::new();
        for (k, (s, d)) in &pairs {
            want.entry(*k)
                .and_modify(|acc| acc.add_assign_signed(d, *s))
                .or_insert_with(|| d.scale(*s));
        }
        if folded.len() != want.len() {
            return Err(format!("{} keys, want {}", folded.len(), want.len()));
        }
        for (k, w) in &want {
            let got = folded.get(k).ok_or_else(|| format!("missing key {k}"))?;
            if !w.allclose(got, 1e-9) {
                return Err(format!("key {k}: diff {}", w.max_abs_diff(got)));
            }
        }
        Ok(())
    });
}

#[test]
fn single_positive_operand_reuses_arc_across_the_shuffle() {
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let payload = Arc::new(DenseMatrix::random(8, 8, 42));
    let other = Arc::new(DenseMatrix::random(8, 8, 43));
    let pairs = vec![
        (0u32, (1.0f64, payload.clone())),
        (1u32, (-1.0f64, payload.clone())),
        (1u32, (1.0f64, other.clone())),
    ];
    let out = ctx
        .parallelize(pairs, 1)
        .fold_by_key("fold", 2, |v: SignedBlock| v, signed_merge, signed_merge)
        .collect("c");
    assert_eq!(out.len(), 2);
    for (k, acc) in out {
        let fin = signed_finalize(acc);
        match k {
            // Single positive operand: the payload Arc crosses untouched.
            0 => assert!(Arc::ptr_eq(&fin, &payload), "singleton +1 group must share the Arc"),
            // Merged group: other − payload.
            1 => assert!(other.sub(&payload).allclose(&fin, 1e-12)),
            _ => panic!("unexpected key {k}"),
        }
    }
}

#[test]
fn serve_round_trip_matches_naive() {
    use stark::config::{build_backend, BackendKind};
    use stark::matrix::multiply::matmul_naive;
    use stark::serve::{request, Server, ServerState};
    use stark::util::json::Value;

    let session = stark::api::StarkSession::builder()
        .cluster(ClusterConfig::new(2, 1))
        .backend(build_backend(BackendKind::Packed, 1).unwrap())
        .build()
        .unwrap();
    let state = ServerState {
        session,
        default_splits: stark::cost::Splits::Fixed(2),
        max_inflight_jobs: 4,
        job_runners: 1,
    };
    let mut server = Server::start("127.0.0.1:0", state).unwrap();
    let a = DenseMatrix::random(8, 8, 7);
    let b = DenseMatrix::random(8, 8, 8);
    let to_json = |m: &DenseMatrix| {
        Value::Array(
            (0..m.rows())
                .map(|r| {
                    Value::Array((0..m.cols()).map(|c| Value::num(m.get(r, c))).collect())
                })
                .collect(),
        )
    };
    let resp = request(
        &server.addr().to_string(),
        &Value::obj(vec![
            ("op", Value::str("multiply")),
            ("algo", Value::str("stark")),
            ("b", Value::num(4.0)),
            ("a", to_json(&a)),
            ("b_mat", to_json(&b)),
            ("return_c", Value::Bool(true)),
        ]),
    )
    .unwrap();
    assert_eq!(resp.get("ok"), Some(&Value::Bool(true)), "{resp:?}");
    let want = matmul_naive(&a, &b);
    let rows = resp.get("c").unwrap().as_array().unwrap();
    assert_eq!(rows.len(), 8);
    for (r, rowv) in rows.iter().enumerate() {
        for (c, x) in rowv.as_array().unwrap().iter().enumerate() {
            let got = x.as_f64().unwrap();
            assert!(
                (want.get(r, c) - got).abs() < 1e-9,
                "({r},{c}): {} vs {got}",
                want.get(r, c)
            );
        }
    }
    server.stop();
}
