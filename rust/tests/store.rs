//! Integration: the named-matrix store (DESIGN.md S22) through the
//! public session API — split-once semantics across concurrent jobs,
//! byte-budget eviction as a property over random op sequences,
//! spill/reload bit-identity, restart recovery, drop-while-running
//! pinning, and a chaos soak over store-backed operands.

use std::sync::Arc;

use stark::algos::Algorithm;
use stark::api::{DistMatrix, StarkSession};
use stark::cost::Splits;
use stark::engine::{ChaosConfig, ClusterConfig};
use stark::matrix::DenseMatrix;
use stark::store::{payload_hash, DropOutcome, MatrixStore};
use stark::util::prop::assert_prop;
use stark::util::prop::Draw;
use stark::util::tmp::TempDir;

fn session_with(budget: Option<u64>, dir: Option<&str>) -> StarkSession {
    let mut cc = ClusterConfig::new(2, 2);
    cc.store_byte_budget = budget;
    cc.store_dir = dir.map(str::to_string);
    StarkSession::builder().cluster(cc).build().unwrap()
}

fn multiply(a: &DistMatrix, b: &DistMatrix) -> DenseMatrix {
    a.multiply(b).algorithm(Algorithm::Stark).splits(Splits::Fixed(2)).collect().unwrap().c
}

/// One `put` + N concurrent multiplies: the stored operand's block
/// split is computed exactly once (splits_computed == 1), and every
/// product is bit-identical to the re-upload (plain handle) path.
#[test]
fn one_put_many_concurrent_multiplies_split_once() {
    let s = session_with(None, None);
    let n = 32;
    let am = DenseMatrix::random(n, n, 1);
    let bm = DenseMatrix::random(n, n, 2);
    s.put("A", Arc::new(am.clone())).unwrap();
    let hb = s.matrix(&bm);
    let products: Vec<DenseMatrix> = std::thread::scope(|scope| {
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                let hb = hb.clone();
                scope.spawn(move || multiply(&s.get("A").unwrap(), &hb))
            })
            .collect();
        threads.into_iter().map(|t| t.join().unwrap()).collect()
    });
    assert_eq!(
        s.store_metrics().splits_computed,
        1,
        "N concurrent jobs over one put must split the operand exactly once"
    );
    // Re-upload path: fresh handles over the same payloads, same bits.
    let want = multiply(&s.matrix(&am), &s.matrix(&bm));
    for p in &products {
        assert_eq!(p.as_slice(), want.as_slice(), "store-backed product diverged");
    }
}

/// Property: whatever sequence of put/drop/get+split ops runs, the
/// store's resident bytes never exceed the budget once no pins are
/// outstanding (pinned entries may transiently overshoot — they cannot
/// be evicted without invalidating live jobs).
#[test]
fn prop_eviction_never_exceeds_budget_when_unpinned() {
    assert_prop("store byte budget", 0x5702_E000, 12, |rng| {
        let budget = (rng.range(1, 9) * 512) as u64;
        let store = MatrixStore::open(None, Some(budget)).map_err(|e| e.to_string())?;
        let names = ["a", "b", "c", "d"];
        for step in 0..30 {
            let name = *rng.choice(&names);
            match rng.range(0, 3) {
                0 => {
                    let n = rng.range(1, 9);
                    store
                        .put(name, Arc::new(DenseMatrix::random(n, n, rng.next_u64())))
                        .map_err(|e| e.to_string())?;
                }
                1 => {
                    let _ = store.drop_name(name);
                }
                _ => {
                    if let Ok(h) = store.get(name) {
                        store.splits_for(h.id(), 8, 2).map_err(|e| e.to_string())?;
                        drop(h); // release the pin before the invariant check
                    }
                }
            }
            let m = store.metrics();
            if m.resident_bytes > budget {
                return Err(format!(
                    "step {step}: resident {} > budget {budget} with zero pins",
                    m.resident_bytes
                ));
            }
        }
        Ok(())
    });
}

/// Budget 0 forces an immediate spill after put; `get` reloads the
/// payload from disk bit-identically, verified by the on-disk checksum.
#[test]
fn spill_and_reload_is_bit_identical_and_checksummed() {
    let tmp = TempDir::new("stark-store-itest").unwrap();
    let dir = tmp.path().to_str().unwrap().to_string();
    let s = session_with(Some(0), Some(&dir));
    let a = DenseMatrix::random(16, 16, 7);
    s.put("w", Arc::new(a.clone())).unwrap();
    let m = s.store_metrics();
    assert_eq!(m.resident_bytes, 0, "budget 0 must spill the payload immediately: {m:?}");
    assert!(m.spills >= 1, "{m:?}");
    let listing = s.store().list();
    let info = &listing[0];
    assert!(!info.resident);
    assert_eq!(info.hash, payload_hash(&a), "on-disk checksum must cover the exact payload");
    let h = s.get("w").unwrap();
    assert_eq!(h.dense().as_slice(), a.as_slice(), "reload must be bit-identical");
    assert!(s.store_metrics().misses >= 1, "the reload is a recorded miss");
}

/// A store directory outlives its session: a new session over the same
/// directory sees the entries and reloads them bit-identically.
#[test]
fn restart_recovers_entries_across_sessions() {
    let tmp = TempDir::new("stark-store-itest").unwrap();
    let dir = tmp.path().to_str().unwrap().to_string();
    let a = DenseMatrix::random(24, 24, 99);
    {
        let s = session_with(None, Some(&dir));
        s.put("persist", Arc::new(a.clone())).unwrap();
        s.put("doomed", Arc::new(DenseMatrix::random(8, 8, 1))).unwrap();
        assert_eq!(s.drop_matrix("doomed").unwrap(), DropOutcome::Dropped);
    }
    let s = session_with(None, Some(&dir));
    assert!(s.get("doomed").is_err(), "dropped names must not survive the restart");
    let h = s.get("persist").unwrap();
    assert_eq!(h.dense().as_slice(), a.as_slice(), "restart reload must be bit-identical");
    // The reloaded entry serves jobs exactly like a fresh put.
    let want = multiply(&s.matrix(&a), &s.matrix(&a));
    let got = multiply(&h, &s.get("persist").unwrap());
    assert_eq!(got.as_slice(), want.as_slice());
}

/// Satellite regression: dropping a name while jobs hold its handles
/// must not invalidate them — `drop` reports Pinned, the in-flight
/// multiplies finish bit-exactly, and the entry goes with the last pin.
#[test]
fn drop_while_jobs_in_flight_keeps_products_bit_exact() {
    let s = session_with(None, None);
    let n = 32;
    let am = DenseMatrix::random(n, n, 11);
    let bm = DenseMatrix::random(n, n, 12);
    s.put("A", Arc::new(am.clone())).unwrap();
    s.put("B", Arc::new(bm.clone())).unwrap();
    let want = multiply(&s.matrix(&am), &s.matrix(&bm));
    let pairs: Vec<(DistMatrix, DistMatrix)> =
        (0..3).map(|_| (s.get("A").unwrap(), s.get("B").unwrap())).collect();
    std::thread::scope(|scope| {
        let threads: Vec<_> = pairs
            .into_iter()
            .map(|(ha, hb)| scope.spawn(move || multiply(&ha, &hb)))
            .collect();
        // Drop both names while the jobs run: the handles pin the
        // entries, so the drops unbind the names but defer removal.
        assert_eq!(s.drop_matrix("A").unwrap(), DropOutcome::Pinned);
        assert_eq!(s.drop_matrix("B").unwrap(), DropOutcome::Pinned);
        assert!(s.get("A").is_err(), "the name is unbound immediately");
        for t in threads {
            assert_eq!(
                t.join().unwrap().as_slice(),
                want.as_slice(),
                "a drop mid-job corrupted a product"
            );
        }
    });
    // Scope joined → every handle released → the entries are gone.
    assert_eq!(s.store_metrics().entries, 0);
}

/// Chaos soak over store-backed operands (budget 0, so spill/reload is
/// in the loop): every recovered run must be bit-identical to the
/// chaos-free store-backed product.
#[test]
fn chaos_soak_over_store_backed_operands_is_bit_identical() {
    let n = 32;
    let am = DenseMatrix::random(n, n, 0xAB);
    let bm = DenseMatrix::random(n, n, 0xCD);
    let clean = {
        let s = session_with(None, None);
        s.put("A", Arc::new(am.clone())).unwrap();
        s.put("B", Arc::new(bm.clone())).unwrap();
        multiply(&s.get("A").unwrap(), &s.get("B").unwrap())
    };
    assert_prop("store chaos soak", 0x5C0A_B500, 6, |rng| {
        let rate = 0.02 + rng.next_f64() * 0.15;
        let mode = rng.range(0, 3);
        let mut cc = ClusterConfig::new(2, 2);
        // Generous retry budget so the soak pins recovery, not retry
        // exhaustion (see tests/chaos.rs for the rationale).
        cc.max_task_attempts = 12;
        cc.chaos = Some(ChaosConfig {
            seed: rng.next_u64(),
            fail_rate: if mode == 0 { rate } else { 0.0 },
            panic_rate: if mode == 1 { rate } else { 0.0 },
            slow_rate: if mode == 2 { rate } else { 0.0 },
            slow_factor: 4.0,
            executor_loss_rate: 0.0,
            stage_contains: None,
            fail_once_partition: None,
        });
        cc.store_byte_budget = Some(0);
        let s = StarkSession::builder().cluster(cc).build().map_err(|e| e.to_string())?;
        s.put("A", Arc::new(am.clone())).map_err(|e| e.to_string())?;
        s.put("B", Arc::new(bm.clone())).map_err(|e| e.to_string())?;
        let ha = s.get("A").map_err(|e| e.to_string())?;
        let hb = s.get("B").map_err(|e| e.to_string())?;
        let out = ha
            .multiply(&hb)
            .algorithm(Algorithm::Stark)
            .splits(Splits::Fixed(2))
            .collect()
            .map_err(|e| format!("mode {mode} rate {rate:.3}: {e}"))?;
        if out.c.as_slice() != clean.as_slice() {
            return Err(format!("mode {mode} rate {rate:.3}: product diverged under chaos"));
        }
        Ok(())
    });
}
