//! Integration tests for the distributed expression DAG (DESIGN.md
//! S18): single-collect chaining, bit-identity against the
//! collect-between baseline, split-time operand fusion, cost-model
//! chain reordering, and a randomized DAG property check against the
//! dense reference.

use stark::algos::stark::predicted_stages;
use stark::algos::Algorithm;
use stark::api::{IntoExpr, StarkSession};
use stark::cost::Splits;
use stark::engine::ClusterConfig;
use stark::matrix::{matmul_naive, DenseMatrix};
use stark::util::prop::{assert_prop, Draw};
use stark::StarkError;

fn session() -> StarkSession {
    StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap()
}

/// The PR's acceptance criterion: `(A·B + C)·Dᵀ` chained collects
/// exactly once — no intermediate gather or re-distribution — and the
/// result is bit-identical to collecting between every op.
#[test]
fn chained_acceptance_pipeline_single_collect_bit_identical() {
    let n = 24; // divisible by b=4, not a power of two
    let b = 4;
    let am = DenseMatrix::random(n, n, 1);
    let bm = DenseMatrix::random(n, n, 2);
    let cm = DenseMatrix::random(n, n, 3);
    let dm = DenseMatrix::random(n, n, 4);

    // Chained: one job, every multiply pinned to stark b=4.
    let s = session();
    let (a, bb) = (s.matrix(&am), s.matrix(&bm));
    let (c, d) = (s.matrix(&cm), s.matrix(&dm));
    let chained = a
        .multiply(&bb)
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(b))
        .add(&c)
        .multiply_with(&d.transpose(), Algorithm::Stark, Splits::Fixed(b))
        .collect()
        .unwrap();

    // Collect-between baseline: gather the product, add on the driver,
    // re-upload, transpose on the driver, multiply again.
    let s2 = session();
    let r1 = s2
        .matrix(&am)
        .multiply(&s2.matrix(&bm))
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(b))
        .collect()
        .unwrap();
    let sum = r1.c.add(&cm);
    let r2 = s2
        .matrix(&sum)
        .multiply(&s2.matrix(&dm.transpose()))
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(b))
        .collect()
        .unwrap();

    // Bit-identical result, and numerically the dense reference.
    assert_eq!(chained.c.as_slice(), r2.c.as_slice(), "chained != collect-between");
    let want = matmul_naive(&matmul_naive(&am, &bm).add(&cm), &dm.transpose());
    assert!(want.allclose(&chained.c, 1e-9));

    // Exactly one gather for the whole pipeline…
    let labels: Vec<&str> = chained.job.stages.iter().map(|s| s.label.as_str()).collect();
    assert_eq!(labels.iter().filter(|l| **l == "result/collect").count(), 1, "{labels:?}");
    // …no elementwise shuffle (the +C folded into a narrow map), no
    // re-gridding, no per-node collects:
    assert!(
        !labels.iter().any(|l| l.contains("ew") || l.contains("regrid")),
        "unexpected intermediate stages: {labels:?}"
    );
    // Two stark multiplies minus their collects, plus the one gather.
    assert_eq!(chained.job.stages.len(), 2 * (predicted_stages(b) - 1) + 1, "{labels:?}");
    assert!(labels.iter().any(|l| l.starts_with("m1/divide")));
    assert!(labels.iter().any(|l| l.starts_with("m2/divide")));

    // The baseline pays the two extra gathers.
    let baseline_stages = r1.job.stages.len() + r2.job.stages.len();
    assert_eq!(baseline_stages, 2 * predicted_stages(b));

    // Bit-stable rerun of the same chain on a fresh session.
    let s3 = session();
    let (a3, b3) = (s3.matrix(&am), s3.matrix(&bm));
    let (c3, d3) = (s3.matrix(&cm), s3.matrix(&dm));
    let again = a3
        .multiply(&b3)
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(b))
        .add(&c3)
        .multiply_with(&d3.transpose(), Algorithm::Stark, Splits::Fixed(b))
        .collect()
        .unwrap();
    assert_eq!(chained.c.as_slice(), again.c.as_slice(), "rerun not bit-stable");

    // The rendered plan names the acceptance expression.
    assert_eq!(chained.plan.expression, "(A·B+C)·Dᵀ");
    assert_eq!(chained.plan.multiplies.len(), 2);
}

/// `(A+B)·C` fuses the sum into the operand's block split: same stage
/// structure as a plain multiply (no elementwise stage anywhere), and
/// bit-identical to adding on the driver first.
#[test]
fn operand_sum_fuses_into_the_split() {
    let n = 16;
    let b = 4;
    let am = DenseMatrix::random(n, n, 11);
    let bm = DenseMatrix::random(n, n, 12);
    let cm = DenseMatrix::random(n, n, 13);

    let s = session();
    let fused = s
        .matrix(&am)
        .add(&s.matrix(&bm))
        .multiply_with(&s.matrix(&cm), Algorithm::Stark, Splits::Fixed(b))
        .collect()
        .unwrap();

    // Driver-side baseline: materialize A+B, then one plain multiply.
    let s2 = session();
    let baseline = s2
        .matrix(&am.add(&bm))
        .multiply(&s2.matrix(&cm))
        .algorithm(Algorithm::Stark)
        .splits(Splits::Fixed(b))
        .collect()
        .unwrap();

    assert_eq!(fused.c.as_slice(), baseline.c.as_slice());
    // Identical stage structure: the sum costs no stage at all.
    assert_eq!(fused.job.stages.len(), baseline.job.stages.len());
    assert!(!fused.job.stages.iter().any(|st| st.label.contains("ew")));
    assert!(matmul_naive(&am.add(&bm), &cm).allclose(&fused.c, 1e-9));
}

/// A sum of two *distributed* products needs exactly one elementwise
/// fold stage — still no intermediate collect.
#[test]
fn sum_of_products_folds_distributed() {
    let n = 16;
    let am = DenseMatrix::random(n, n, 21);
    let bm = DenseMatrix::random(n, n, 22);
    let cm = DenseMatrix::random(n, n, 23);
    let dm = DenseMatrix::random(n, n, 24);
    let s = session();
    let (a, b) = (s.matrix(&am), s.matrix(&bm));
    let (c, d) = (s.matrix(&cm), s.matrix(&dm));
    let report = a.multiply(&b).expr().add(&c.expr().multiply(&d)).collect().unwrap();
    let want = matmul_naive(&am, &bm).add(&matmul_naive(&cm, &dm));
    assert!(want.allclose(&report.c, 1e-9));
    let labels: Vec<&str> = report.job.stages.iter().map(|s| s.label.as_str()).collect();
    assert_eq!(labels.iter().filter(|l| **l == "result/collect").count(), 1, "{labels:?}");
    assert_eq!(labels.iter().filter(|l| l.contains("/add")).count(), 1, "{labels:?}");
}

/// Chain planning reorders `(A·B)·C` into `A·(B·C)` when the §IV model
/// says so — and the reorder is observable in the plan, the grids, and
/// a correct result (the big intermediate never materializes as a
/// 256-grid product feeding another 256-grid multiply).
#[test]
fn chain_planning_reorders_rectangular_chains() {
    let am = DenseMatrix::random(8, 8, 31);
    let bm = DenseMatrix::random(8, 256, 32);
    let cm = DenseMatrix::random(256, 8, 33);
    let s = session();
    let (a, b, c) = (s.matrix(&am), s.matrix(&bm), s.matrix(&cm));

    // The user writes left-assoc; the planner prefers right-assoc.
    let expr = a.multiply(&b).then_multiply(&c);
    let plan = expr.plan().unwrap();
    assert!(plan.reordered, "expected a reorder: {plan:?}");
    assert_eq!(plan.multiplies.len(), 2);
    // First the 256-grid B·C, then the 8-grid A·(BC).
    assert_eq!(plan.multiplies[0].plan.n, 256, "{plan:?}");
    assert_eq!(plan.multiplies[1].plan.n, 8, "{plan:?}");

    let report = expr.collect().unwrap();
    let want = matmul_naive(&matmul_naive(&am, &bm), &cm);
    assert!(want.allclose(&report.c, 1e-8), "Δ={}", want.max_abs_diff(&report.c));
    // The 256-grid product regrids down to the 8-grid consumer —
    // distributed, not collected.
    let labels: Vec<&str> = report.job.stages.iter().map(|s| s.label.as_str()).collect();
    assert_eq!(labels.iter().filter(|l| **l == "result/collect").count(), 1, "{labels:?}");
    assert!(labels.iter().any(|l| l.starts_with("regrid")), "{labels:?}");

    // Square chains stay exactly as written.
    let sq = session();
    let (x, y, z) = (
        sq.matrix(&DenseMatrix::random(16, 16, 41)),
        sq.matrix(&DenseMatrix::random(16, 16, 42)),
        sq.matrix(&DenseMatrix::random(16, 16, 43)),
    );
    let sq_plan = x.multiply(&y).then_multiply(&z).plan().unwrap();
    assert!(!sq_plan.reordered);

    // Pinned nodes are chain barriers: no reorder even when it would pay.
    let s2 = session();
    let (a2, b2, c2) = (s2.matrix(&am), s2.matrix(&bm), s2.matrix(&cm));
    let pinned = a2
        .multiply(&b2)
        .algorithm(Algorithm::Mllib)
        .splits(Splits::Fixed(2))
        .then_multiply(&c2);
    let pinned_plan = pinned.plan().unwrap();
    assert!(!pinned_plan.reordered);
    assert_eq!(pinned_plan.multiplies[0].plan.algorithm, Algorithm::Mllib);
}

/// `pow` builds shared squarings: planning three multiplies for `P^8`,
/// with the chained result matching repeated dense squaring.
#[test]
fn pow_is_shared_squarings_with_one_collect() {
    // Scaled down so P^8 magnitudes stay O(1) and an absolute tolerance
    // is meaningful.
    let pm = DenseMatrix::random(24, 24, 51).scale(1.0 / 24.0);
    let s = session();
    let p = s.matrix(&pm);
    let report = p.pow(8).collect().unwrap();
    let mut want = pm.clone();
    for _ in 0..3 {
        want = matmul_naive(&want, &want);
    }
    assert!(want.allclose(&report.c, 1e-7), "Δ={}", want.max_abs_diff(&report.c));
    assert_eq!(report.plan.multiplies.len(), 3);
    let collects = report
        .job
        .stages
        .iter()
        .filter(|st| st.label == "result/collect")
        .count();
    assert_eq!(collects, 1);
    // pow(0) stays a typed error.
    assert!(matches!(p.pow(0).collect(), Err(StarkError::InvalidExpression(_))));
}

/// Randomized DAGs of ·/+/−/ᵀ/scale over odd and padded shapes match
/// the dense reference, and re-running the same DAG is bit-stable.
#[test]
fn random_expression_dags_match_dense_reference() {
    assert_prop("expr-dag", 0xE1AB, 12, |rng| {
        let n = *rng.choice(&[3usize, 5, 8, 12, 16]);
        let s = session();
        // Pool of (expression, dense reference) pairs, grown by random ops.
        let mut pool: Vec<(stark::DistExpr, DenseMatrix)> = (0..2)
            .map(|i| {
                let m = DenseMatrix::random(n, n, 0x9000 + i);
                (s.matrix(&m).expr(), m)
            })
            .collect();
        let ops = rng.range(1, 5);
        for _ in 0..ops {
            let i = rng.range(0, pool.len());
            let j = rng.range(0, pool.len());
            let (ei, di) = pool[i].clone();
            let (ej, dj) = pool[j].clone();
            let pick = rng.range(0, 5);
            let next = match pick {
                0 => (ei.add(&ej), di.add(&dj)),
                1 => (ei.sub(&ej), di.sub(&dj)),
                2 => (ei.scale(-0.5), di.scale(-0.5)),
                3 => (ei.transpose(), di.transpose()),
                _ => (ei.multiply(&ej), matmul_naive(&di, &dj)),
            };
            pool.push(next);
        }
        let (expr, want) = pool.last().unwrap().clone();
        let got = expr.collect().map_err(|e| format!("collect failed: {e}"))?;
        if (got.c.rows(), got.c.cols()) != (want.rows(), want.cols()) {
            return Err(format!(
                "shape {}x{} != {}x{}",
                got.c.rows(),
                got.c.cols(),
                want.rows(),
                want.cols()
            ));
        }
        if !want.allclose(&got.c, 1e-7) {
            return Err(format!(
                "value drift {} on n={n} expr {}",
                want.max_abs_diff(&got.c),
                got.plan.expression
            ));
        }
        // Exactly one collect, whatever the DAG shape.
        let collects = got
            .job
            .stages
            .iter()
            .filter(|st| st.label == "result/collect")
            .count();
        if collects != 1 {
            return Err(format!("{collects} collects in {}", got.plan.expression));
        }
        // Bit-stable rerun.
        let again = expr.collect().map_err(|e| format!("rerun failed: {e}"))?;
        if got.c.as_slice() != again.c.as_slice() {
            return Err(format!("rerun not bit-stable for {}", got.plan.expression));
        }
        Ok(())
    });
}
