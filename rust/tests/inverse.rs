//! Numerical-correctness battery for distributed block-recursive
//! inversion and linear solves (DESIGN.md S23). The contract under
//! test: residuals stay within the documented conditioning bound
//! `‖A·Â⁻¹ − I‖_F ≤ c·n·ε·κ̂(A)` (with `κ̂ = ‖A‖_F·‖A⁻¹‖_F` a
//! computable upper proxy for the condition number), results are
//! bit-stable across reruns, the distributed recursion agrees with the
//! dense LU reference at awkward (odd / non-power-of-two) shapes
//! including the identity-padding regression at n = 100, a solve
//! collects exactly once, and singular or near-singular inputs come
//! back as typed [`StarkError::SingularMatrix`] — never a panic, never
//! NaN-poisoned output.

use stark::api::StarkSession;
use stark::engine::ClusterConfig;
use stark::matrix::{lu, matmul_naive, DenseMatrix};
use stark::util::prop::{assert_prop, Draw};
use stark::StarkError;

/// Generous constant in the residual bound `c·n·ε·κ̂`. Covers the
/// error growth of the quadrant recursion (six multiplies plus two
/// recursive inversions per level) on top of plain LU's `O(n·ε)`.
const RESIDUAL_C: f64 = 100.0;

fn session() -> StarkSession {
    StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build().unwrap()
}

/// Strictly diagonally dominant: off-diagonal entries in (−1, 1),
/// diagonal shifted by `n`. Nonsingular with κ₂ = O(1).
fn diag_dominant(n: usize, seed: u64) -> DenseMatrix {
    let mut a = DenseMatrix::random(n, n, seed);
    for i in 0..n {
        a.set(i, i, a.get(i, i) + n as f64);
    }
    a
}

/// Random SPD: `GᵀG + n·I` pushes every eigenvalue into `[n, n + ‖G‖²]`,
/// so conditioning stays mild at any size this suite uses.
fn spd(n: usize, seed: u64) -> DenseMatrix {
    let g = DenseMatrix::random(n, n, seed);
    let mut a = matmul_naive(&g.transpose(), &g);
    for i in 0..n {
        a.set(i, i, a.get(i, i) + n as f64);
    }
    a
}

/// `κ̂ = ‖A‖_F·‖Â⁻¹‖_F` — overestimates κ₂ (by up to a factor n), which
/// only loosens the bound; it never hides a real residual blow-up.
fn kappa_hat(a: &DenseMatrix, ainv: &DenseMatrix) -> f64 {
    a.frobenius() * ainv.frobenius()
}

/// `‖A·Â⁻¹ − I‖_F`.
fn identity_residual(a: &DenseMatrix, ainv: &DenseMatrix) -> f64 {
    let mut r = matmul_naive(a, ainv);
    for i in 0..r.rows() {
        r.set(i, i, r.get(i, i) - 1.0);
    }
    r.frobenius()
}

/// Property: over random sizes (including odd and non-power-of-two,
/// which exercise the identity-padding path) and both matrix families,
/// the distributed inverse satisfies the conditioning-scaled residual
/// bound and contains no non-finite entry.
#[test]
fn inverse_residual_stays_within_the_conditioning_bound() {
    assert_prop("inverse-residual", 0x1A7E_57ED, 10, |rng| {
        let n = rng.range(5, 33);
        let spd_kind = *rng.choice(&[false, true]);
        let seed = rng.next_u64();
        let a = if spd_kind { spd(n, seed) } else { diag_dominant(n, seed) };

        let s = session();
        let report = s
            .matrix(&a)
            .inverse()
            .collect()
            .map_err(|e| format!("inverse failed at n={n} spd={spd_kind}: {e}"))?;
        let ainv = report.c;
        if !ainv.as_slice().iter().all(|x| x.is_finite()) {
            return Err(format!("non-finite entry in the inverse at n={n} spd={spd_kind}"));
        }
        let bound = RESIDUAL_C * n as f64 * f64::EPSILON * kappa_hat(&a, &ainv);
        let residual = identity_residual(&a, &ainv);
        if residual > bound {
            return Err(format!(
                "residual {residual:.3e} exceeds bound {bound:.3e} at n={n} spd={spd_kind}"
            ));
        }
        Ok(())
    });
}

/// Property: `solve(A, B)` keeps `‖A·X − B‖_F` within the bound scaled
/// by `‖B‖_F`, works for rectangular right-hand sides, and its job
/// ledger shows exactly one `result/collect` — the whole solve runs as
/// one job.
#[test]
fn solve_residual_stays_within_the_conditioning_bound_and_collects_once() {
    assert_prop("solve-residual", 0x50_1BED, 10, |rng| {
        let n = rng.range(5, 33);
        let m = rng.range(1, 9);
        let spd_kind = *rng.choice(&[false, true]);
        let seed = rng.next_u64();
        let a = if spd_kind { spd(n, seed) } else { diag_dominant(n, seed) };
        let b = DenseMatrix::random(n, m, seed ^ 0xB0B);

        let s = session();
        let report = s
            .matrix(&a)
            .solve(&s.matrix(&b))
            .collect()
            .map_err(|e| format!("solve failed at n={n} m={m} spd={spd_kind}: {e}"))?;
        let x = report.c;
        if (x.rows(), x.cols()) != (n, m) {
            return Err(format!("solve shape {}×{}, wanted {n}×{m}", x.rows(), x.cols()));
        }
        if !x.as_slice().iter().all(|v| v.is_finite()) {
            return Err(format!("non-finite entry in the solution at n={n} m={m}"));
        }
        let ainv = lu::invert(&a).map_err(|e| format!("reference LU failed: {e}"))?;
        let bound =
            RESIDUAL_C * n as f64 * f64::EPSILON * kappa_hat(&a, &ainv) * (1.0 + b.frobenius());
        let residual = matmul_naive(&a, &x).sub(&b).frobenius();
        if residual > bound {
            return Err(format!(
                "solve residual {residual:.3e} exceeds bound {bound:.3e} at n={n} m={m}"
            ));
        }
        let collects = report.job.stages.iter().filter(|st| st.label == "result/collect").count();
        if collects != 1 {
            return Err(format!("solve collected {collects} times, wanted exactly 1"));
        }
        Ok(())
    });
}

/// Pin: reruns of the same inversion and solve — fresh sessions, same
/// inputs — are bit-identical, and `pow(-1)` is the same expression as
/// `inverse()` down to the bits.
#[test]
fn inversion_and_solve_are_bit_stable_across_reruns() {
    let n = 24;
    let a = diag_dominant(n, 0xB17_57AB);
    let b = DenseMatrix::random(n, 3, 0xB17_57AC);

    let run_inv = || session().matrix(&a).inverse().collect().unwrap().c;
    let first = run_inv();
    let second = run_inv();
    assert_eq!(first.as_slice(), second.as_slice(), "inverse rerun not bit-identical");

    let via_pow = session().matrix(&a).pow(-1).collect().unwrap().c;
    assert_eq!(first.as_slice(), via_pow.as_slice(), "pow(-1) differs from inverse()");

    let run_solve = || {
        let s = session();
        s.matrix(&a).solve(&s.matrix(&b)).collect().unwrap().c
    };
    assert_eq!(run_solve().as_slice(), run_solve().as_slice(), "solve rerun not bit-identical");
}

/// The distributed recursion agrees with the dense LU reference at
/// awkward shapes: odd, non-power-of-two, and the n = 100 `b = auto`
/// identity-padding regression. A zero-padded recursion would hand the
/// dense leaf a singular trailing block at every one of these sizes —
/// identity padding `diag(A, I)` keeps the padded operand invertible
/// and the crop exact.
#[test]
fn distributed_inverse_matches_dense_lu_at_awkward_shapes() {
    for (n, seed) in [(7usize, 71u64), (24, 72), (33, 73), (100, 74)] {
        let a = diag_dominant(n, seed);
        let reference = lu::invert(&a).unwrap();
        let report = session().matrix(&a).inverse().collect().unwrap();
        assert!(
            report.c.as_slice().iter().all(|x| x.is_finite()),
            "non-finite entry at n={n} — identity-padding regression"
        );
        assert!(
            report.c.allclose(&reference, 1e-8),
            "distributed inverse disagrees with dense LU at n={n} (max diff {:.3e})",
            report.c.max_abs_diff(&reference)
        );
        // The planner's schedule for this size exactly halves down to
        // its dense-LU crossover.
        let inv_plan = &report.plan.inversions[0].plan;
        for w in inv_plan.levels.windows(2) {
            assert_eq!(w[0], 2 * w[1], "non-halving level in {:?}", inv_plan.levels);
        }
        assert_eq!(*inv_plan.levels.last().unwrap(), inv_plan.leaf);
    }
}

/// Ledger shape of a solve: one job, exactly one `result/collect`, one
/// planned inversion node, and — whenever the planner chose a real
/// recursion (crossover below the padded dimension) — the recursion's
/// internal multiply stages visible under the `inv1/` prefix, none of
/// them a second collect.
#[test]
fn solve_ledger_has_one_collect_and_recursion_stages_under_the_inv_prefix() {
    let n = 24;
    let a = diag_dominant(n, 0x1ED6E5);
    let b = DenseMatrix::random(n, 2, 0x1ED6E6);
    let s = session();
    let report = s.matrix(&a).solve(&s.matrix(&b)).collect().unwrap();

    assert_eq!(report.plan.inversions.len(), 1);
    assert_eq!(report.plan.inversions[0].label, "inv1");
    let labels: Vec<&str> = report.job.stages.iter().map(|st| st.label.as_str()).collect();
    assert_eq!(
        labels.iter().filter(|l| **l == "result/collect").count(),
        1,
        "solve must collect exactly once: {labels:?}"
    );
    if report.plan.inversions[0].plan.depth() > 0 {
        assert!(
            labels.iter().any(|l| l.starts_with("inv1/")),
            "recursion planned but no inv1/ stages in the ledger: {labels:?}"
        );
    }
}

/// Returns `a` with column 0 scaled by `f` — `f = 0.0` is exactly
/// singular, and a tiny `f` is numerically singular (every pivot
/// candidate in the first elimination column sits below LU's
/// `n·ε·max|A|` round-off floor).
fn scaled_first_column(a: &DenseMatrix, f: f64) -> DenseMatrix {
    let mut m = a.clone();
    for i in 0..m.rows() {
        m.set(i, 0, m.get(i, 0) * f);
    }
    m
}

/// Singular and near-singular inputs surface as
/// [`StarkError::SingularMatrix`] from every public path — the dense
/// leaf (small n), the distributed recursion (n past the padding
/// boundary), inversion, and solve. Never a panic, never a NaN-poisoned
/// result, and the session keeps working afterwards.
#[test]
fn singular_inputs_are_typed_errors_on_every_path_and_never_wedge() {
    for (n, what) in [(6usize, "dense leaf"), (24, "distributed recursion")] {
        for (f, kind) in [(0.0, "singular"), (1e-30, "near-singular")] {
            let a = scaled_first_column(&diag_dominant(n, 0xDE6E + n as u64), f);
            let s = session();
            let err = s.matrix(&a).inverse().collect().unwrap_err();
            match err {
                StarkError::SingularMatrix { pivot, .. } => {
                    assert!(pivot.abs() < 1e-9, "reported pivot {pivot:e} is not tiny");
                }
                other => panic!("{kind} {what} inverse: expected SingularMatrix, got {other}"),
            }
            let b = DenseMatrix::random(n, 2, 7);
            match s.matrix(&a).solve(&s.matrix(&b)).collect().unwrap_err() {
                StarkError::SingularMatrix { .. } => {}
                other => panic!("{kind} {what} solve: expected SingularMatrix, got {other}"),
            }
            // No wedge: the same session still runs clean work.
            let good = diag_dominant(n, 0xC1EA + n as u64);
            let after = s.matrix(&good).inverse().collect().unwrap();
            assert!(after.c.as_slice().iter().all(|x| x.is_finite()));
        }
    }
}
