//! Integration: sparklet engine semantics under composition — multi-op
//! chains, branching with cache, stage accounting across whole jobs.

use std::collections::BTreeMap;
use std::sync::Arc;

use stark::engine::{ChaosConfig, ClusterConfig, HashPartitioner, SparkContext};

fn ctx(execs: usize, cores: usize) -> SparkContext {
    SparkContext::new(ClusterConfig::new(execs, cores))
}

#[test]
fn wordcount_style_pipeline() {
    // The canonical Spark program: tokenize -> map 1 -> reduceByKey.
    let ctx = ctx(2, 2);
    let docs = vec![
        "the quick brown fox".to_string(),
        "the lazy dog".to_string(),
        "the quick dog jumps".to_string(),
    ];
    let counts: BTreeMap<String, u64> = ctx
        .parallelize(docs, 2)
        .flat_map(|line| line.split(' ').map(String::from).collect::<Vec<_>>())
        .map(|w| (w, 1u64))
        .reduce_by_key("wc", 4, |a, b| a + b)
        .collect("c")
        .into_iter()
        .collect();
    assert_eq!(counts["the"], 3);
    assert_eq!(counts["quick"], 2);
    assert_eq!(counts["dog"], 2);
    assert_eq!(counts["fox"], 1);
    // the, quick, brown, fox, lazy, dog, jumps
    assert_eq!(counts.len(), 7);
}

#[test]
fn chained_shuffles() {
    // groupByKey -> re-key -> reduceByKey -> join, across 3 shuffles.
    let ctx = ctx(2, 2);
    let pairs: Vec<(u32, u32)> = (0..60).map(|i| (i % 6, i)).collect();
    let grouped = ctx.parallelize(pairs, 5).group_by_key("s1", 3);
    let sums = grouped
        .map(|(k, vs)| (k % 2, vs.into_iter().map(u64::from).sum::<u64>()))
        .reduce_by_key("s2", 2, |a, b| a + b);
    let labels = ctx.parallelize(vec![(0u32, "even"), (1u32, "odd")], 1);
    let mut joined = sums.join("s3", &labels, 2).collect("c");
    joined.sort();
    // Σ 0..60 = 1770; keys 0,2,4 (k%2==0) hold i with i%6 ∈ {0,2,4}.
    let even: u64 = (0..60).filter(|i| (i % 6) % 2 == 0).sum::<u64>().into();
    let odd: u64 = (0..60).filter(|i| (i % 6) % 2 == 1).sum::<u64>().into();
    assert_eq!(joined, vec![(0, (even, "even")), (1, (odd, "odd"))]);
}

#[test]
fn branching_with_cache_runs_once_per_branch() {
    let ctx = ctx(2, 1);
    let job = ctx.run_job("branching");
    let base = job.parallelize((0u64..100).collect(), 4).map(|x| x * 3).cache("materialize");
    let s1: u64 = base.map(|x| x).collect("branch1").iter().sum();
    let s2 = base.filter(|x| x % 2 == 0).count("branch2");
    assert_eq!(s1, 3 * 99 * 100 / 2);
    assert_eq!(s2, 50);
    let stages = job.stages();
    assert_eq!(stages.len(), 3, "{:?}", stages.iter().map(|s| &s.label).collect::<Vec<_>>());
}

#[test]
fn stage_metrics_accumulate_comp_and_shuffle() {
    let ctx = ctx(2, 2);
    let scope = ctx.run_job("metrics");
    let pairs: Vec<(u32, Vec<f64>)> = (0..16).map(|i| (i % 4, vec![1.0; 100])).collect();
    scope.parallelize(pairs, 4).group_by_key("shuffle", 4).collect("gather");
    let job = scope.finish();
    assert_eq!(job.stages.len(), 2);
    let shuffle = &job.stages[0];
    assert_eq!(shuffle.label, "shuffle");
    assert_eq!(shuffle.records_out, 16);
    assert_eq!(shuffle.shuffle_bytes, 16 * (4 + 800));
    assert!(shuffle.pf <= 4);
    let gather = &job.stages[1];
    assert_eq!(gather.shuffle_bytes, 0);
}

#[test]
fn empty_and_single_element_datasets() {
    let ctx = ctx(2, 2);
    let empty: Vec<u64> = vec![];
    let d = ctx.parallelize(empty, 3);
    assert_eq!(d.collect("c").len(), 0);
    assert_eq!(d.count("n"), 0);
    let single = ctx.parallelize(vec![(1u32, 2u64)], 4);
    let grouped = single.group_by_key("g", 2).collect("c");
    assert_eq!(grouped, vec![(1, vec![2])]);
}

#[test]
fn skewed_keys_all_land_together() {
    // All records share one key: one group holds everything.
    let ctx = ctx(3, 1);
    let pairs: Vec<(u8, u64)> = (0..500).map(|i| (7u8, i)).collect();
    let grouped = ctx.parallelize(pairs, 10).group_by_key("skew", 5).collect("c");
    assert_eq!(grouped.len(), 1);
    assert_eq!(grouped[0].1.len(), 500);
}

#[test]
fn partition_by_respects_partitioner() {
    let ctx = ctx(2, 2);
    let pairs: Vec<(u64, u64)> = (0..64).map(|i| (i, i)).collect();
    let part = Arc::new(HashPartitioner::new(8));
    let d = ctx.parallelize(pairs, 4).partition_by("pb", part.clone());
    assert_eq!(d.num_partitions(), 8);
    // After partition_by, map_partitions sees co-partitioned keys.
    let ok = d
        .map_partitions(move |records| {
            let parts: std::collections::HashSet<usize> = records
                .iter()
                .map(|(k, _)| {
                    use stark::engine::Partitioner;
                    part.partition(k)
                })
                .collect();
            vec![parts.len() <= 1]
        })
        .collect("check");
    assert!(ok.into_iter().all(|x| x));
}

#[test]
fn retry_preserves_exactly_once_output() {
    let mut cc = ClusterConfig::new(2, 2);
    cc.chaos = Some(ChaosConfig::fail_once("wc", 1));
    let ctx = SparkContext::new(cc);
    let pairs: Vec<(u32, u64)> = (0..100).map(|i| (i % 10, 1)).collect();
    let mut out = ctx.parallelize(pairs, 4).reduce_by_key("wc", 4, |a, b| a + b).collect("c");
    out.sort();
    // No duplicated or lost contributions despite the retried task.
    assert_eq!(out, (0..10).map(|k| (k, 10u64)).collect::<Vec<_>>());
}

#[test]
fn union_then_shuffle() {
    let ctx = ctx(2, 2);
    let a = ctx.parallelize((0u32..10).map(|i| (i % 2, 1u64)).collect::<Vec<_>>(), 2);
    let b = ctx.parallelize((0u32..10).map(|i| (i % 2, 10u64)).collect::<Vec<_>>(), 3);
    let mut out = a.union(&b).reduce_by_key("u", 2, |x, y| x + y).collect("c");
    out.sort();
    assert_eq!(out, vec![(0, 55), (1, 55)]);
}

#[test]
fn large_fan_out_flat_map() {
    let ctx = ctx(2, 2);
    let d = ctx.parallelize((0u64..32).collect(), 4);
    let expanded = d.flat_map(|x| (0..x % 5).map(|j| x * 100 + j).collect::<Vec<_>>());
    let total: usize = expanded.count("c");
    let want: usize = (0..32).map(|x| (x % 5) as usize).sum();
    assert_eq!(total, want);
}
