//! Integration: the four distributed algorithms against the single-node
//! reference across a grid of (n, b, cluster shape) — the core
//! correctness contract of the coordinator.

use std::sync::Arc;

use stark::algos::{marlin, mllib, stark as stark_algo, Algorithm, BaselineOptions, StarkConfig};
use stark::api::StarkSession;
use stark::cost::Splits;
use stark::engine::{ChaosConfig, ClusterConfig, SparkContext};
use stark::matrix::{matmul_parallel, DenseMatrix};
use stark::runtime::NativeBackend;

const BASE: BaselineOptions = BaselineOptions { isolate_multiply: false };

fn reference(n: usize, seed: u64) -> (DenseMatrix, DenseMatrix, DenseMatrix) {
    let a = DenseMatrix::random(n, n, seed);
    let b = DenseMatrix::random(n, n, seed + 1);
    let c = matmul_parallel(&a, &b, 4);
    (a, b, c)
}

#[test]
fn all_algorithms_agree_with_reference_across_grid() {
    for (n, bs) in [(64usize, vec![1usize, 2, 4, 8]), (128, vec![2, 8, 16])] {
        let (a, b, want) = reference(n, n as u64);
        for &bb in &bs {
            for (execs, cores) in [(1usize, 1usize), (2, 2), (3, 1)] {
                let ctx = SparkContext::new(ClusterConfig::new(execs, cores));
                let backend = Arc::new(NativeBackend::default());
                let cfg = StarkConfig::default();
                let s = stark_algo::multiply(&ctx, backend.clone(), &a, &b, bb, &cfg).unwrap();
                assert!(
                    want.allclose(&s.c, 1e-9),
                    "stark n={n} b={bb} cluster={execs}x{cores}: Δ={}",
                    want.max_abs_diff(&s.c)
                );
                let m = marlin::multiply(&ctx, backend.clone(), &a, &b, bb, &BASE).unwrap();
                assert!(want.allclose(&m.c, 1e-9), "marlin n={n} b={bb}");
                let l = mllib::multiply(&ctx, backend.clone(), &a, &b, bb, &BASE).unwrap();
                assert!(want.allclose(&l.c, 1e-9), "mllib n={n} b={bb}");
            }
        }
    }
}

#[test]
fn executor_count_does_not_change_results() {
    let (a, b, _) = reference(64, 7);
    let mut results = Vec::new();
    for execs in [1usize, 2, 4, 8] {
        let ctx = SparkContext::new(ClusterConfig::new(execs, 1));
        let out =
            stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &b, 4, &StarkConfig::default())
                .unwrap();
        results.push(out.c);
    }
    // Partitioning changes FP summation order (as on real Spark), so
    // demand agreement to within a few ulps, not bitwise equality.
    for r in &results[1..] {
        assert!(
            results[0].max_abs_diff(r) < 1e-12,
            "results differ across executor counts: {}",
            results[0].max_abs_diff(r)
        );
    }
}

#[test]
fn fused_leaf_is_bit_identical_in_structure() {
    let (a, b, want) = reference(64, 9);
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    for b_parts in [2usize, 4, 8] {
        let cfg = StarkConfig { fused_leaf: true, ..Default::default() };
        let out =
            stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &b, b_parts, &cfg)
                .unwrap();
        assert!(want.allclose(&out.c, 1e-9), "fused b={b_parts}");
    }
}

#[test]
fn leaf_call_law_stark_vs_baselines() {
    let (a, b, _) = reference(64, 11);
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let backend = Arc::new(NativeBackend::default());
    for (bb, stark_want, cube) in [(2usize, 7u64, 8u64), (4, 49, 64), (8, 343, 512)] {
        let s =
            stark_algo::multiply(&ctx, backend.clone(), &a, &b, bb, &StarkConfig::default())
                .unwrap();
        assert_eq!(s.leaf_calls, stark_want);
        let m = marlin::multiply(&ctx, backend.clone(), &a, &b, bb, &BASE).unwrap();
        assert_eq!(m.leaf_calls, cube);
        let l = mllib::multiply(&ctx, backend.clone(), &a, &b, bb, &BASE).unwrap();
        assert_eq!(l.leaf_calls, cube);
    }
}

#[test]
fn failure_injection_in_every_stark_phase_recovers() {
    let (a, b, want) = reference(64, 13);
    for phase in ["divide", "multiply", "combine", "result"] {
        let mut cc = ClusterConfig::new(2, 2);
        cc.chaos = Some(ChaosConfig::fail_once(phase, 0));
        let ctx = SparkContext::new(cc);
        let out =
            stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &b, 4, &StarkConfig::default())
                .unwrap();
        let retries: u32 = out.job.stages.iter().map(|s| s.retries).sum();
        assert_eq!(retries, 1, "phase {phase}: no retry recorded");
        assert_eq!(
            out.job.total_attempts(),
            out.job.total_tasks() + 1,
            "phase {phase}: attempts should exceed tasks by the one retry"
        );
        assert!(want.allclose(&out.c, 1e-9), "phase {phase}: wrong result after recovery");
    }
}

#[test]
fn failure_injection_in_baselines_recovers() {
    let (a, b, want) = reference(64, 17);
    for phase in ["stage3", "stage4"] {
        let mut cc = ClusterConfig::new(2, 2);
        cc.chaos = Some(ChaosConfig::fail_once(phase, 0));
        let ctx = SparkContext::new(cc);
        let backend = Arc::new(NativeBackend::default());
        let m = marlin::multiply(&ctx, backend.clone(), &a, &b, 4, &BASE).unwrap();
        assert!(want.allclose(&m.c, 1e-9), "marlin {phase}");
        ctx.cluster().rearm_failure();
        let l = mllib::multiply(&ctx, backend, &a, &b, 4, &BASE).unwrap();
        assert!(want.allclose(&l.c, 1e-9), "mllib {phase}");
    }
}

#[test]
fn special_matrices() {
    let n = 32;
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let backend = Arc::new(NativeBackend::default());
    let cfg = StarkConfig::default();
    let i = DenseMatrix::identity(n);
    let z = DenseMatrix::zeros(n, n);
    let r = DenseMatrix::random(n, n, 21);

    let out = stark_algo::multiply(&ctx, backend.clone(), &i, &r, 4, &cfg).unwrap();
    assert!(out.c.allclose(&r, 1e-12), "I @ R != R");
    let out = stark_algo::multiply(&ctx, backend.clone(), &r, &z, 4, &cfg).unwrap();
    assert!(out.c.allclose(&z, 0.0), "R @ 0 != 0");
    // Permutation-ish: reversal matrix.
    let p = DenseMatrix::from_fn(n, n, |r_, c| if c == n - 1 - r_ { 1.0 } else { 0.0 });
    let out = stark_algo::multiply(&ctx, backend, &p, &r, 4, &cfg).unwrap();
    let want = DenseMatrix::from_fn(n, n, |r_, c| r.get(n - 1 - r_, c));
    assert!(out.c.allclose(&want, 1e-12), "row reversal wrong");
}

#[test]
fn metrics_are_recorded_per_job() {
    let (a, b, _) = reference(64, 23);
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));
    let s =
        stark_algo::multiply(&ctx, Arc::new(NativeBackend::default()), &a, &b, 4, &StarkConfig::default())
            .unwrap();
    assert_eq!(s.job.stages.len(), stark_algo::predicted_stages(4));
    assert!(s.job.wall_ms > 0.0);
    assert!(s.job.total_shuffle_bytes() > 0);
    assert!(s.job.phase_ms("divide") >= 0.0);
    // Phases appear in execution order: divide before multiply before combine.
    let phases: Vec<String> = s.job.phase_wall_ms().into_iter().map(|(p, _)| p).collect();
    let pos = |name: &str| phases.iter().position(|p| p == name).unwrap();
    assert!(pos("divide") < pos("multiply"));
    assert!(pos("multiply") < pos("combine"));
}

#[test]
fn algorithm_enum_roundtrip() {
    for algo in Algorithm::ALL {
        let parsed: Algorithm = algo.to_string().parse().unwrap();
        assert_eq!(parsed, algo);
    }
    assert!("nonsense".parse::<Algorithm>().is_err());
}

#[test]
fn isolate_multiply_does_not_change_numbers() {
    let (a, b, want) = reference(64, 29);
    let session = StarkSession::builder()
        .cluster(ClusterConfig::new(2, 2))
        .stark_options(StarkConfig { isolate_multiply: true, ..Default::default() })
        .build()
        .unwrap();
    let (ha, hb) = (session.matrix(&a), session.matrix(&b));
    for algo in Algorithm::ALL {
        let req = ha.multiply(&hb).algorithm(algo).splits(Splits::Fixed(4));
        if algo == Algorithm::Cannon {
            // Cannon's 16-slot gang cannot be admitted on this 4-core
            // cluster: the planner rejects the request before anything
            // is distributed, so the handle-reuse counts below hold.
            let err = req.collect().unwrap_err();
            assert!(
                matches!(
                    err,
                    stark::error::StarkError::InvalidSplits {
                        algorithm: Algorithm::Cannon,
                        b: 4,
                        ..
                    }
                ),
                "cannon on a too-small cluster should be a typed plan error, got: {err}"
            );
            continue;
        }
        let out = req.collect().unwrap();
        assert!(want.allclose(&out.c, 1e-9), "{algo} isolate_multiply");
        assert_eq!(out.plan.algorithm, algo);
    }
    // Handle reuse across the shuffle-based systems: one distribution
    // each side (cannon errored at plan time, before distribution).
    assert_eq!(ha.splits_computed(), 1);
    assert_eq!(hb.splits_computed(), 1);
}
