"""Make the in-repo ``compile`` package importable no matter where
pytest is invoked from (repo root via ``python -m pytest python/tests``,
or ``python/`` directly)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
