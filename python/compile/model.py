"""L2 JAX compute graphs for Stark's leaf operations.

Each function returns a jit-able JAX callable over *static* block shapes;
``aot.py`` lowers them to HLO text once per (kernel, block size, dtype) and
the Rust coordinator executes the artifacts via PJRT on the request path.

The graphs call the L1 Pallas kernels so the kernels lower into the same
HLO module. ``strassen_leaf`` is the fused variant: one XLA program runs
the full one-level Strassen step (14 divide additions, 7 tile-pipelined
multiplications, 8 combine additions) over a 2x2 quadrant split — this is
what the coordinator dispatches when a Stark recursion bottoms out one
level above the block size (ablation: 7 separate ``matmul`` calls).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from . import kernels

_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def dtype_of(name: str):
    """Map manifest dtype names (``f32``/``f64``) to jnp dtypes."""
    try:
        return _DTYPES[name]
    except KeyError:
        raise ValueError(f"unknown dtype {name!r}; expected one of {sorted(_DTYPES)}")


def block_matmul() -> Callable:
    """``C = A @ B`` on a single block via the L1 tiled kernel."""

    def fn(x, y):
        return (kernels.matmul(x, y),)

    return fn


def block_add() -> Callable:
    """Pairwise block add (divide/combine unit step)."""

    def fn(x, y):
        return (kernels.add(x, y),)

    return fn


def block_sub() -> Callable:
    """Pairwise block subtract (divide/combine unit step)."""

    def fn(x, y):
        return (kernels.sub(x, y),)

    return fn


def block_mterms() -> Callable:
    """Divide-phase fused additions: 8 quadrants -> 14 M-term operands."""

    def fn(*quads):
        return kernels.mterms(*quads)

    return fn


def block_combine7() -> Callable:
    """Combine-phase fused additions: M1..M7 -> C11, C12, C21, C22."""

    def fn(*ms):
        return kernels.strassen_combine(*ms)

    return fn


def strassen_leaf() -> Callable:
    """One full Strassen level over quadrants, fused into one XLA program.

    Inputs: ``a11, a12, a21, a22, b11, b12, b21, b22`` (each ``(s, s)``);
    outputs: ``c11, c12, c21, c22``. 7 multiplications, 22 additions.
    """

    def fn(a11, a12, a21, a22, b11, b12, b21, b22):
        ops = kernels.mterms(a11, a12, a21, a22, b11, b12, b21, b22)
        ms = [kernels.matmul(ops[i], ops[7 + i]) for i in range(7)]
        return kernels.strassen_combine(*ms)

    return fn


def strassen_recursive(depth: int) -> Callable:
    """Full in-graph Strassen recursion (validation/ablation only).

    The distributed system never lowers this — the recursion is the Rust
    coordinator's job — but lowering it for small sizes lets tests compare
    the coordinator's stage-by-stage results against a single fused graph.
    """

    def fn(a, b):
        return (kernels.ref.strassen_recursive(a, b, depth),)

    return fn
