"""Build-time Python for Stark: L1 Pallas kernels, L2 JAX graphs, AOT lowering.

Nothing in this package runs on the request path — ``make artifacts``
invokes :mod:`compile.aot` once, and the Rust coordinator consumes the
emitted HLO-text artifacts via PJRT thereafter.
"""
