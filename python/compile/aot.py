"""AOT pipeline: lower L2 graphs to HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

For every (kernel, block size, dtype) in the grid this writes
``<name>.hlo.txt`` plus a single ``manifest.json`` that the Rust runtime
parses to discover artifact shapes and arity.

Interchange format is HLO **text**, not ``lowered.compile().serialize()``:
the ``xla`` crate links xla_extension 0.5.1 which rejects jax>=0.5
serialized protos (64-bit instruction ids, ``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. Lowering goes through
stablehlo -> XlaComputation with ``return_tuple=True`` so the Rust side
always unwraps one tuple (see /opt/xla-example/gen_hlo.py).

Two multiply implementations are emitted per size (DESIGN.md §6 ablation):

- ``impl=pallas`` — the L1 tiled Pallas kernel, lowered via interpret mode
  (a fori-loop of VMEM-tile dots; structure matches the TPU pipeline).
- ``impl=dot`` — plain ``jnp.matmul`` (single HLO dot, Eigen gemm on the
  CPU PJRT backend); the production default for the CPU runtime, exactly
  as the paper's leaf multiply defers to BLAS.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import kernels, model  # noqa: E402

DEFAULT_SIZES = (16, 32, 64, 128, 256, 512, 1024)
DEFAULT_DTYPES = ("f64", "f32")


@dataclass
class Spec:
    """One artifact to lower: a callable plus its example input shapes."""

    name: str
    kind: str  # matmul | add | sub | mterms | combine7 | strassen_leaf
    impl: str  # pallas | dot
    dtype: str
    block: int
    fn: Callable
    num_inputs: int
    num_outputs: int
    input_shape: tuple[int, int]
    meta: dict = field(default_factory=dict)


def _dot_matmul():
    def fn(x, y):
        return (jnp.matmul(x, y),)

    return fn


def _dot_strassen_leaf():
    def fn(a11, a12, a21, a22, b11, b12, b21, b22):
        ops = kernels.ref.mterms(a11, a12, a21, a22, b11, b12, b21, b22)
        ms = [jnp.matmul(ops[i], ops[7 + i]) for i in range(7)]
        return kernels.ref.strassen_combine(*ms)

    return fn


def build_specs(sizes: Sequence[int], dtypes: Sequence[str]) -> list[Spec]:
    """The artifact grid. Element-wise kernels are emitted once per size
    (pallas impl only — there is nothing to ablate for VPU adds)."""
    specs: list[Spec] = []
    for dt in dtypes:
        for s in sizes:
            shape = (s, s)
            specs.append(
                Spec(f"matmul_pallas_{dt}_{s}", "matmul", "pallas", dt, s,
                     model.block_matmul(), 2, 1, shape)
            )
            specs.append(
                Spec(f"matmul_dot_{dt}_{s}", "matmul", "dot", dt, s,
                     _dot_matmul(), 2, 1, shape)
            )
            # One-level fused Strassen over (s, s) quadrants.
            specs.append(
                Spec(f"strassen_leaf_pallas_{dt}_{s}", "strassen_leaf", "pallas",
                     dt, s, model.strassen_leaf(), 8, 4, shape)
            )
            specs.append(
                Spec(f"strassen_leaf_dot_{dt}_{s}", "strassen_leaf", "dot",
                     dt, s, _dot_strassen_leaf(), 8, 4, shape)
            )
            specs.append(
                Spec(f"add_{dt}_{s}", "add", "pallas", dt, s,
                     model.block_add(), 2, 1, shape)
            )
            specs.append(
                Spec(f"sub_{dt}_{s}", "sub", "pallas", dt, s,
                     model.block_sub(), 2, 1, shape)
            )
            specs.append(
                Spec(f"mterms_{dt}_{s}", "mterms", "pallas", dt, s,
                     model.block_mterms(), 8, 14, shape)
            )
            specs.append(
                Spec(f"combine7_{dt}_{s}", "combine7", "pallas", dt, s,
                     model.block_combine7(), 7, 4, shape)
            )
    return specs


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: Spec) -> str:
    dtype = model.dtype_of(spec.dtype)
    args = [jax.ShapeDtypeStruct(spec.input_shape, dtype)] * spec.num_inputs
    lowered = jax.jit(spec.fn).lower(*args)
    return to_hlo_text(lowered)


def emit(out_dir: str, sizes: Sequence[int], dtypes: Sequence[str],
         verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    specs = build_specs(sizes, dtypes)
    entries = []
    for spec in specs:
        text = lower_spec(spec)
        fname = f"{spec.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(
            {
                "name": spec.name,
                "file": fname,
                "kind": spec.kind,
                "impl": spec.impl,
                "dtype": spec.dtype,
                "block": spec.block,
                "num_inputs": spec.num_inputs,
                "num_outputs": spec.num_outputs,
                "input_shape": list(spec.input_shape),
                "sha256_16": digest,
                "hlo_bytes": len(text),
            }
        )
        if verbose:
            print(f"  {fname:<40} {len(text):>9} B", file=sys.stderr)
    manifest = {
        "format": 1,
        "jax_version": jax.__version__,
        "default_tile": kernels.DEFAULT_TILE,
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}",
              file=sys.stderr)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)),
                    help="comma-separated block sizes (powers of two)")
    ap.add_argument("--dtypes", default=",".join(DEFAULT_DTYPES),
                    help="comma-separated dtypes (f32,f64)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    for s in sizes:
        if s < 2 or s & (s - 1):
            raise SystemExit(f"block size {s} is not a power of two >= 2")
    dtypes = [d.strip() for d in args.dtypes.split(",") if d.strip()]
    emit(args.out, sizes, dtypes, verbose=not args.quiet)


if __name__ == "__main__":
    main()
