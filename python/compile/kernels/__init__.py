"""L1 Pallas kernels for Stark's leaf-node compute.

Public surface:

- :func:`matmul` — tiled MXU-oriented block multiply (the hot path).
- :func:`mterms` — fused divide-phase additions (8 quadrants -> 14 operands).
- :func:`strassen_combine` — fused combine-phase additions (M1..M7 -> C).
- :func:`add` / :func:`sub` — pairwise block add/subtract.
- ``ref`` — the pure-jnp oracle module.

All kernels run under ``interpret=True`` (see matmul.py docstring).
"""

from .combine import add, mterms, strassen_combine, sub
from .matmul import DEFAULT_TILE, matmul, mxu_utilization_estimate, vmem_bytes
from . import ref

__all__ = [
    "DEFAULT_TILE",
    "add",
    "matmul",
    "mterms",
    "mxu_utilization_estimate",
    "ref",
    "strassen_combine",
    "sub",
    "vmem_bytes",
]
