"""L1 Pallas kernel: tiled block matrix multiplication.

This is the leaf-node multiply of the Stark recursion — the role BLAS
(via Breeze/JNI) plays in the paper. The kernel is written for the TPU
execution model and adapted per DESIGN.md §Hardware-Adaptation:

- The input matrices are tiled into ``(TM, TK)`` / ``(TK, TN)`` VMEM-resident
  blocks via ``BlockSpec``; the grid iterates ``(M/TM, N/TN, K/TK)`` with the
  K dimension innermost so the output tile acts as an accumulator that stays
  resident while a row-panel of X and a column-panel of Y stream through
  VMEM (the HBM<->VMEM schedule the paper expressed with Spark partitions).
- Tiles default to 128x128 — the MXU-native systolic shape — and the inner
  product is issued with ``preferred_element_type`` so the MXU accumulates
  at full precision.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO which both the pytest
oracle checks and the Rust runtime execute. On a real TPU the same kernel
compiles to an MXU pipeline; VMEM footprint estimates are recorded in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. 3 tiles (x, y, acc) * 128*128*8B (f64) = 384 KiB,
# comfortably below the ~16 MiB VMEM budget; see DESIGN.md §Hardware-Adaptation.
DEFAULT_TILE = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """Grid point (i, j, k): o[i,j] += x[i,k] @ y[k,j].

    The output BlockSpec maps every k to the same (i, j) tile, so ``o_ref``
    is the VMEM-resident accumulator across the innermost K loop.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_tile(dim: int, requested: int | None) -> int:
    """Largest power-of-two tile <= requested that divides ``dim``."""
    tile = min(requested or DEFAULT_TILE, dim)
    while dim % tile != 0:
        tile //= 2
    if tile < 1:
        raise ValueError(f"no valid tile for dim={dim}")
    return tile


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    tile_m: int | None = None,
    tile_n: int | None = None,
    tile_k: int | None = None,
) -> jax.Array:
    """Multiply ``x @ y`` with the tiled Pallas kernel.

    Both operands must be 2-D with matching contraction dims. Tile sizes
    default to :data:`DEFAULT_TILE`, clamped down to the largest power of
    two dividing each dimension.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    if x.dtype != y.dtype:
        raise ValueError(f"dtype mismatch: {x.dtype} vs {y.dtype}")

    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    tk = _pick_tile(k, tile_k)
    n_k = k // tk

    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // tm, n // tn, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(tile_m: int, tile_n: int, tile_k: int, itemsize: int) -> int:
    """VMEM residency estimate for one grid step (x tile + y tile + acc)."""
    return itemsize * (tile_m * tile_k + tile_k * tile_n + tile_m * tile_n)


def mxu_utilization_estimate(tile_m: int, tile_n: int, tile_k: int) -> float:
    """Fraction of MXU 128x128x128 issue slots filled by one tile matmul.

    Structure-only estimate (interpret mode gives numpy wallclock, not TPU):
    a (TM, TK) x (TK, TN) tile multiply occupies ceil(TM/128)*ceil(TN/128)*
    ceil(TK/128) MXU passes; utilization is the filled fraction of those.
    """

    def _ceil(a: int, b: int) -> int:
        return -(-a // b)

    passes = _ceil(tile_m, 128) * _ceil(tile_n, 128) * _ceil(tile_k, 128)
    ideal = (tile_m / 128) * (tile_n / 128) * (tile_k / 128)
    return ideal / passes
