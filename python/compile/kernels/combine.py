"""L1 Pallas kernels: element-wise Strassen combine steps.

Three fused element-wise kernels cover every non-multiplication step of one
Strassen level (paper Algorithm 1):

- :func:`mterms` — the *divide* additions: 8 quadrant blocks in, the 14
  multiplicand operands of M1..M7 out (7 left, 7 right).
- :func:`strassen_combine` — the *combine* additions: M1..M7 in, the 4
  product quadrants C11..C22 out.
- :func:`add` / :func:`sub` — single pairwise block add/subtract, the unit
  operation the distributed divide/combine phases apply per matrix block.

All are VPU (element-wise) work on TPU; fusing them into single kernels
saves HBM round-trips between the 18 additions of a Strassen step — the
kernel-level analogue of the paper fusing its additions into one flatMap.
Tiled with the same VMEM BlockSpec discipline as the matmul kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import DEFAULT_TILE, _pick_tile


def _elementwise_call(kernel, inputs, n_out: int):
    """Run ``kernel`` over equally-shaped 2-D inputs with a tiled grid."""
    shape = inputs[0].shape
    dtype = inputs[0].dtype
    for a in inputs:
        if a.shape != shape or a.dtype != dtype:
            raise ValueError("all operands must share shape and dtype")
    m, n = shape
    tm = _pick_tile(m, DEFAULT_TILE)
    tn = _pick_tile(n, DEFAULT_TILE)
    spec = pl.BlockSpec((tm, tn), lambda i, j: (i, j))
    out = pl.pallas_call(
        kernel,
        grid=(m // tm, n // tn),
        in_specs=[spec] * len(inputs),
        out_specs=[spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct(shape, dtype)] * n_out,
        interpret=True,
    )(*inputs)
    return tuple(out)


def _mterms_kernel(
    a11, a12, a21, a22, b11, b12, b21, b22,
    l1, l2, l3, l4, l5, l6, l7, r1, r2, r3, r4, r5, r6, r7,
):
    """Left/right multiplicands of M1..M7 (paper Algorithm 1)."""
    l1[...] = a11[...] + a22[...]
    l2[...] = a21[...] + a22[...]
    l3[...] = a11[...]
    l4[...] = a22[...]
    l5[...] = a11[...] + a12[...]
    l6[...] = a21[...] - a11[...]
    l7[...] = a12[...] - a22[...]
    r1[...] = b11[...] + b22[...]
    r2[...] = b11[...]
    r3[...] = b12[...] - b22[...]
    r4[...] = b21[...] - b11[...]
    r5[...] = b22[...]
    r6[...] = b11[...] + b12[...]
    r7[...] = b21[...] + b22[...]


def mterms(a11, a12, a21, a22, b11, b12, b21, b22):
    """Divide-phase additions: quadrants -> 14 M-term operands.

    Returns ``(L1..L7, R1..R7)`` such that ``M_i = L_i @ R_i``.
    """
    return _elementwise_call(
        _mterms_kernel, [a11, a12, a21, a22, b11, b12, b21, b22], 14
    )


def _combine_kernel(m1, m2, m3, m4, m5, m6, m7, c11, c12, c21, c22):
    """Combine-phase additions: M1..M7 -> C quadrants.

    Note: the paper's Algorithm 1 prints ``C22 = M1 - M2 - M3 + M6``; that is
    a typo for Strassen's standard ``C22 = M1 - M2 + M3 + M6`` (with the
    paper's own M definitions, the printed form is numerically wrong). We
    implement the correct identity and verify against a jnp oracle.
    """
    c11[...] = m1[...] + m4[...] - m5[...] + m7[...]
    c12[...] = m3[...] + m5[...]
    c21[...] = m2[...] + m4[...]
    c22[...] = m1[...] - m2[...] + m3[...] + m6[...]


def strassen_combine(m1, m2, m3, m4, m5, m6, m7):
    """Combine M1..M7 into ``(C11, C12, C21, C22)``."""
    return _elementwise_call(_combine_kernel, [m1, m2, m3, m4, m5, m6, m7], 4)


def _add_kernel(x, y, o):
    o[...] = x[...] + y[...]


def _sub_kernel(x, y, o):
    o[...] = x[...] - y[...]


def add(x, y):
    """Block addition ``x + y`` (divide/combine unit step)."""
    return _elementwise_call(_add_kernel, [x, y], 1)[0]


def sub(x, y):
    """Block subtraction ``x - y`` (divide/combine unit step)."""
    return _elementwise_call(_sub_kernel, [x, y], 1)[0]
