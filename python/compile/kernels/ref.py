"""Pure-jnp oracle for every L1 kernel and L2 graph.

This module is the correctness ground truth: pytest/hypothesis pin the
Pallas kernels (and the AOT artifacts executed from Rust) to these
definitions with ``assert_allclose``. Everything here is straight-line
``jnp`` — no Pallas, no custom calls.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x, y):
    """Reference block multiply."""
    return jnp.matmul(x, y)


def mterms(a11, a12, a21, a22, b11, b12, b21, b22):
    """Reference divide-phase operands ``(L1..L7, R1..R7)``, M_i = L_i @ R_i."""
    ls = (
        a11 + a22,
        a21 + a22,
        a11,
        a22,
        a11 + a12,
        a21 - a11,
        a12 - a22,
    )
    rs = (
        b11 + b22,
        b11,
        b12 - b22,
        b21 - b11,
        b22,
        b11 + b12,
        b21 + b22,
    )
    return ls + rs


def strassen_combine(m1, m2, m3, m4, m5, m6, m7):
    """Reference combine: M1..M7 -> (C11, C12, C21, C22).

    Uses Strassen's correct ``C22 = M1 - M2 + M3 + M6`` (the paper's
    Algorithm 1 misprints the sign of M3 — see kernels/combine.py).
    """
    c11 = m1 + m4 - m5 + m7
    c12 = m3 + m5
    c21 = m2 + m4
    c22 = m1 - m2 + m3 + m6
    return c11, c12, c21, c22


def strassen_leaf(a11, a12, a21, a22, b11, b12, b21, b22):
    """Reference one-level Strassen step on quadrants."""
    ops = mterms(a11, a12, a21, a22, b11, b12, b21, b22)
    ms = [jnp.matmul(ops[i], ops[7 + i]) for i in range(7)]
    return strassen_combine(*ms)


def split(x):
    """Split a square matrix into (x11, x12, x21, x22) quadrants."""
    n = x.shape[0] // 2
    return x[:n, :n], x[:n, n:], x[n:, :n], x[n:, n:]


def assemble(c11, c12, c21, c22):
    """Inverse of :func:`split`."""
    return jnp.block([[c11, c12], [c21, c22]])


def strassen_recursive(a, b, depth: int):
    """Full Strassen recursion to ``depth`` levels, leaves via jnp.matmul.

    Mirrors the serial Algorithm 1 and the distributed recursion's math;
    used to cross-check the Rust coordinator's results at the L2 level.
    """
    if depth <= 0 or a.shape[0] < 2:
        return jnp.matmul(a, b)
    a11, a12, a21, a22 = split(a)
    b11, b12, b21, b22 = split(b)
    ops = mterms(a11, a12, a21, a22, b11, b12, b21, b22)
    ms = [strassen_recursive(ops[i], ops[7 + i], depth - 1) for i in range(7)]
    return assemble(*strassen_combine(*ms))
