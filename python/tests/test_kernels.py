"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: the same
functions lowered here are what the Rust coordinator executes via PJRT.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.kernels import ref

RNG = np.random.default_rng(0xC0FFEE)


def rand(shape, dtype=np.float64, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype=dtype)


def tol(dtype):
    return dict(rtol=1e-10, atol=1e-10) if dtype == np.float64 else dict(
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 64, 128, 256])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_matmul_square(n, dtype):
    x, y = rand((n, n), dtype), rand((n, n), dtype)
    got = kernels.matmul(x, y)
    np.testing.assert_allclose(got, ref.matmul(x, y), **tol(dtype))
    assert got.dtype == dtype


@pytest.mark.parametrize("m,k,n", [(4, 8, 16), (16, 4, 2), (128, 32, 64),
                                   (2, 256, 2), (64, 64, 256)])
def test_matmul_rectangular(m, k, n):
    x, y = rand((m, k)), rand((k, n))
    np.testing.assert_allclose(kernels.matmul(x, y), ref.matmul(x, y),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("tile", [2, 4, 8, 16, 32, 64])
def test_matmul_explicit_tiles(tile):
    """Tiling must not change the result (accumulation order differs)."""
    n = 64
    x, y = rand((n, n)), rand((n, n))
    got = kernels.matmul(x, y, tile_m=tile, tile_n=tile, tile_k=tile)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-9, atol=1e-9)


def test_matmul_tile_larger_than_dim_clamps():
    x, y = rand((8, 8)), rand((8, 8))
    got = kernels.matmul(x, y, tile_m=4096, tile_n=4096, tile_k=4096)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-10, atol=1e-10)


def test_matmul_identity():
    n = 32
    x = rand((n, n))
    eye = jnp.eye(n, dtype=x.dtype)
    np.testing.assert_allclose(kernels.matmul(x, eye), x, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(kernels.matmul(eye, x), x, rtol=1e-12, atol=1e-12)


def test_matmul_zeros():
    n = 16
    z = jnp.zeros((n, n))
    np.testing.assert_array_equal(kernels.matmul(z, rand((n, n))), z)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        kernels.matmul(rand((4, 8)), rand((4, 8)))
    with pytest.raises(ValueError):
        kernels.matmul(rand((4,)), rand((4, 4)))
    with pytest.raises(ValueError):
        kernels.matmul(rand((4, 4), np.float32), rand((4, 4), np.float64))


@pytest.mark.parametrize("n", [2, 8, 32, 128])
def test_mterms_matches_ref(n):
    quads = [rand((n, n)) for _ in range(8)]
    got = kernels.mterms(*quads)
    want = ref.mterms(*quads)
    assert len(got) == 14
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n", [2, 8, 32, 128])
def test_strassen_combine_matches_ref(n):
    ms = [rand((n, n)) for _ in range(7)]
    got = kernels.strassen_combine(*ms)
    want = ref.strassen_combine(*ms)
    assert len(got) == 4
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n", [4, 16, 64])
def test_add_sub(n):
    x, y = rand((n, n)), rand((n, n))
    np.testing.assert_allclose(kernels.add(x, y), x + y, rtol=0, atol=0)
    np.testing.assert_allclose(kernels.sub(x, y), x - y, rtol=0, atol=0)


@pytest.mark.parametrize("n", [2, 8, 32])
def test_strassen_leaf_vs_plain_matmul(n):
    """One fused Strassen level == the plain product, assembled."""
    a, b = rand((2 * n, 2 * n)), rand((2 * n, 2 * n))
    aq, bq = ref.split(a), ref.split(b)
    c11, c12, c21, c22 = ref.strassen_leaf(*aq, *bq)
    got = ref.assemble(c11, c12, c21, c22)
    np.testing.assert_allclose(got, a @ b, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("n", [2, 8, 32])
def test_strassen_leaf_kernel_path(n):
    """The Pallas-kernel leaf (mterms -> matmul -> combine) == plain product."""
    from compile import model

    a, b = rand((2 * n, 2 * n)), rand((2 * n, 2 * n))
    aq, bq = ref.split(a), ref.split(b)
    c = model.strassen_leaf()(*aq, *bq)
    got = ref.assemble(*c)
    np.testing.assert_allclose(got, a @ b, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("depth", [0, 1, 2, 3])
def test_strassen_recursive_depths(depth):
    n = 32
    a, b = rand((n, n)), rand((n, n))
    got = ref.strassen_recursive(a, b, depth)
    np.testing.assert_allclose(got, a @ b, rtol=1e-8, atol=1e-8)


def test_split_assemble_roundtrip():
    x = rand((16, 16))
    np.testing.assert_array_equal(ref.assemble(*ref.split(x)), x)


def test_paper_c22_typo_would_be_wrong():
    """Regression guard for the Algorithm-1 misprint (C22 sign of M3).

    With the paper's printed combine (M1 - M2 - M3 + M6) the product is
    wrong; our implementation uses the standard identity. Keep this test so
    nobody 'fixes' the combine back to the paper's typo.
    """
    n = 4
    a, b = rand((2 * n, 2 * n)), rand((2 * n, 2 * n))
    aq, bq = ref.split(a), ref.split(b)
    ops = ref.mterms(*aq, *bq)
    ms = [ops[i] @ ops[7 + i] for i in range(7)]
    c22_paper = ms[0] - ms[1] - ms[2] + ms[5]
    c22_true = (a @ b)[n:, n:]
    assert not np.allclose(c22_paper, c22_true)


def test_vmem_estimate():
    # 128-tiles of f64: 3 * 128*128*8 = 384 KiB, within a 16 MiB VMEM.
    assert kernels.vmem_bytes(128, 128, 128, 8) == 3 * 128 * 128 * 8
    assert kernels.vmem_bytes(128, 128, 128, 8) < 16 * 2**20


def test_mxu_utilization_estimate():
    assert kernels.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert kernels.mxu_utilization_estimate(64, 64, 64) == pytest.approx(1 / 8)
    assert kernels.mxu_utilization_estimate(256, 256, 256) == 1.0
