"""L2 graph tests: the model-level callables that aot.py lowers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def rand(shape, dtype=np.float64):
    return jnp.asarray(RNG.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("n", [4, 16, 64])
def test_block_matmul_graph(n):
    f = model.block_matmul()
    x, y = rand((n, n)), rand((n, n))
    (got,) = f(x, y)
    np.testing.assert_allclose(got, x @ y, rtol=1e-10, atol=1e-10)


def test_block_add_sub_graphs():
    x, y = rand((8, 8)), rand((8, 8))
    np.testing.assert_array_equal(model.block_add()(x, y)[0], x + y)
    np.testing.assert_array_equal(model.block_sub()(x, y)[0], x - y)


def test_block_mterms_graph_matches_ref():
    quads = [rand((8, 8)) for _ in range(8)]
    got = model.block_mterms()(*quads)
    want = ref.mterms(*quads)
    assert len(got) == 14
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=0, atol=1e-12)


def test_block_combine7_graph_matches_ref():
    ms = [rand((8, 8)) for _ in range(7)]
    got = model.block_combine7()(*ms)
    want = ref.strassen_combine(*ms)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=0, atol=1e-12)


@pytest.mark.parametrize("n", [4, 16])
def test_strassen_leaf_graph_is_the_product(n):
    a, b = rand((2 * n, 2 * n)), rand((2 * n, 2 * n))
    quads = list(ref.split(a)) + list(ref.split(b))
    c = model.strassen_leaf()(*quads)
    np.testing.assert_allclose(ref.assemble(*c), a @ b, rtol=1e-9, atol=1e-9)


def test_strassen_recursive_graph():
    f = model.strassen_recursive(2)
    a, b = rand((16, 16)), rand((16, 16))
    (got,) = f(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("fn_name,num_in", [
    ("block_matmul", 2), ("block_add", 2), ("block_sub", 2),
    ("block_mterms", 8), ("block_combine7", 7), ("strassen_leaf", 8),
])
def test_graphs_are_jittable_and_lowerable(fn_name, num_in):
    """Everything aot.py emits must trace under jit with static shapes."""
    fn = getattr(model, fn_name)()
    args = [jax.ShapeDtypeStruct((8, 8), jnp.float64)] * num_in
    lowered = jax.jit(fn).lower(*args)
    text = lowered.as_text()
    assert "func.func public @main" in text or "ENTRY" in text


def test_strassen_leaf_hlo_has_seven_dots():
    """The fused leaf must lower to exactly 7 contractions (L2 perf
    invariant — EXPERIMENTS.md §Perf)."""
    args = [jax.ShapeDtypeStruct((16, 16), jnp.float64)] * 8
    lowered = jax.jit(model.strassen_leaf()).lower(*args)
    text = lowered.as_text()  # stablehlo
    dots = text.count("dot_general")
    assert dots == 7, f"expected 7 dot_general ops, found {dots}"


def test_dtype_of():
    assert model.dtype_of("f64") == jnp.float64
    assert model.dtype_of("f32") == jnp.float32
    with pytest.raises(ValueError):
        model.dtype_of("bf16")
