"""Shared test config: enable x64 before any jax import in tests.

The paper's matrices are IEEE-754 doubles; all artifact/dtype sweeps
include f64, which requires the x64 flag at process start.
"""

import jax

jax.config.update("jax_enable_x64", True)
