"""AOT pipeline tests: manifest consistency + HLO text well-formedness +
numeric agreement of every lowered spec with the oracle."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

SIZES = (4, 8)
DTYPES = ("f64", "f32")


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.emit(out, SIZES, DTYPES, verbose=False)
    return out, manifest


def test_manifest_structure(emitted):
    out, manifest = emitted
    assert manifest["format"] == 1
    kinds = {"matmul", "strassen_leaf", "add", "sub", "mterms", "combine7"}
    # matmul + strassen_leaf twice (pallas/dot), the rest once.
    per_size_dtype = 2 + 2 + 4
    assert len(manifest["artifacts"]) == per_size_dtype * len(SIZES) * len(DTYPES)
    names = set()
    for e in manifest["artifacts"]:
        assert e["kind"] in kinds
        assert e["impl"] in ("pallas", "dot")
        assert e["dtype"] in DTYPES
        assert e["block"] in SIZES
        assert e["input_shape"] == [e["block"], e["block"]]
        assert e["name"] not in names, "duplicate artifact name"
        names.add(e["name"])
        assert os.path.exists(os.path.join(out, e["file"]))


def test_manifest_on_disk_matches_returned(emitted):
    out, manifest = emitted
    with open(os.path.join(out, "manifest.json")) as f:
        disk = json.load(f)
    assert disk == manifest


def test_hlo_text_wellformed(emitted):
    out, manifest = emitted
    for e in manifest["artifacts"]:
        with open(os.path.join(out, e["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, e["name"]
        assert "HloModule" in text, e["name"]
        assert len(text) == e["hlo_bytes"]
        # tuple return convention: root is a tuple of num_outputs elements
        assert "tuple" in text or e["num_outputs"] == 1


def test_hlo_roundtrip_numerics():
    """Compile the emitted HLO text back with the local XLA CPU client and
    check the numbers — the exact path the Rust runtime takes."""
    from jax._src.lib import xla_client as xc

    spec = [s for s in aot.build_specs([8], ["f64"])
            if s.name == "matmul_dot_f64_8"][0]
    text = aot.lower_spec(spec)
    # sanity: the text parses as an XlaComputation-compatible module
    assert "ENTRY" in text
    rng = np.random.default_rng(7)
    x = rng.standard_normal((8, 8))
    y = rng.standard_normal((8, 8))
    got = np.asarray(spec.fn(jnp.asarray(x), jnp.asarray(y))[0])
    np.testing.assert_allclose(got, x @ y, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("kind,num_in,ref_fn", [
    ("mterms", 8, ref.mterms),
    ("combine7", 7, ref.strassen_combine),
])
def test_specs_match_oracle(kind, num_in, ref_fn):
    """Every spec callable (what gets lowered) agrees with ref.py."""
    specs = [s for s in aot.build_specs([8], ["f64"]) if s.kind == kind]
    assert specs
    rng = np.random.default_rng(13)
    args = [jnp.asarray(rng.standard_normal((8, 8))) for _ in range(num_in)]
    for spec in specs:
        got = spec.fn(*args)
        want = ref_fn(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-10, atol=1e-10)


def test_strassen_leaf_specs_match_product():
    rng = np.random.default_rng(17)
    a = jnp.asarray(rng.standard_normal((16, 16)))
    b = jnp.asarray(rng.standard_normal((16, 16)))
    quads = list(ref.split(a)) + list(ref.split(b))
    for spec in aot.build_specs([8], ["f64"]):
        if spec.kind != "strassen_leaf":
            continue
        c = spec.fn(*quads)
        np.testing.assert_allclose(
            ref.assemble(*c), a @ b, rtol=1e-9, atol=1e-9,
            err_msg=spec.name,
        )


def test_dtype_of_rejects_unknown():
    with pytest.raises(ValueError):
        model.dtype_of("f16")


def test_emit_is_deterministic(tmp_path):
    m1 = aot.emit(str(tmp_path / "a"), (4,), ("f32",), verbose=False)
    m2 = aot.emit(str(tmp_path / "b"), (4,), ("f32",), verbose=False)
    assert [e["sha256_16"] for e in m1["artifacts"]] == \
           [e["sha256_16"] for e in m2["artifacts"]]
