"""Property-based sweeps: kernel shapes/dtypes/tilings vs the jnp oracle.

Hypothesis drives the Pallas kernels over the full supported domain
(power-of-two dims, both dtypes, arbitrary tile choices) and pins them to
``ref.py`` with assert_allclose, per the repo testing contract.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)

pow2 = st.sampled_from([2, 4, 8, 16, 32, 64])
dtypes = st.sampled_from([np.float32, np.float64])


def _arr(data, shape, dtype):
    n = int(np.prod(shape))
    vals = data.draw(
        st.lists(
            st.floats(-100, 100, allow_nan=False, allow_infinity=False, width=32),
            min_size=n, max_size=n,
        )
    )
    return jnp.asarray(np.array(vals, dtype=dtype).reshape(shape))


def _tol(dtype):
    return dict(rtol=1e-9, atol=1e-7) if dtype == np.float64 else dict(
        rtol=1e-3, atol=1e-2)


@settings(**SETTINGS)
@given(st.data(), pow2, pow2, pow2, dtypes)
def test_matmul_property(data, m, k, n, dtype):
    x = _arr(data, (m, k), dtype)
    y = _arr(data, (k, n), dtype)
    got = kernels.matmul(x, y)
    assert got.shape == (m, n) and got.dtype == dtype
    np.testing.assert_allclose(got, ref.matmul(x, y), **_tol(dtype))


@settings(**SETTINGS)
@given(st.data(), pow2, st.sampled_from([2, 4, 8, 16, 32, 128]))
def test_matmul_tiling_invariance(data, n, tile):
    """Any tile choice yields the same product (mod fp reassociation)."""
    x = _arr(data, (n, n), np.float64)
    y = _arr(data, (n, n), np.float64)
    got = kernels.matmul(x, y, tile_m=tile, tile_n=tile, tile_k=tile)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-9, atol=1e-7)


@settings(**SETTINGS)
@given(st.data(), pow2, dtypes)
def test_mterms_property(data, n, dtype):
    quads = [_arr(data, (n, n), dtype) for _ in range(8)]
    got = kernels.mterms(*quads)
    want = ref.mterms(*quads)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=0, atol=1e-5)


@settings(**SETTINGS)
@given(st.data(), pow2, dtypes)
def test_combine_property(data, n, dtype):
    ms = [_arr(data, (n, n), dtype) for _ in range(7)]
    got = kernels.strassen_combine(*ms)
    want = ref.strassen_combine(*ms)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-4)


@settings(**SETTINGS)
@given(st.data(), st.sampled_from([2, 4, 8, 16]))
def test_strassen_leaf_property(data, n):
    """Fused leaf == plain product on arbitrary inputs."""
    a = _arr(data, (2 * n, 2 * n), np.float64)
    b = _arr(data, (2 * n, 2 * n), np.float64)
    c = ref.strassen_leaf(*ref.split(a), *ref.split(b))
    np.testing.assert_allclose(
        ref.assemble(*c), jnp.matmul(a, b), rtol=1e-8, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(st.data(), st.sampled_from([4, 8, 16, 32]), st.integers(0, 4))
def test_strassen_recursive_property(data, n, depth):
    a = _arr(data, (n, n), np.float64)
    b = _arr(data, (n, n), np.float64)
    got = ref.strassen_recursive(a, b, depth)
    np.testing.assert_allclose(got, jnp.matmul(a, b), rtol=1e-7, atol=1e-5)
