//! Partition tuning: trace the paper's U-shaped running-time curve
//! (Fig. 9) and find the optimal partition count for a matrix size.
//!
//! Demonstrates the trade-off §V-C analyzes: small `b` ⇒ huge leaf blocks
//! and little parallelism; large `b` ⇒ deep recursion and communication
//! overhead. Also overlays the §IV cost model's prediction.
//!
//! ```bash
//! cargo run --release --example partition_tuning
//! ```

use stark::algos::Algorithm;
use stark::config::BackendKind;
use stark::cost::{self, Planner, Splits};
use stark::experiments::{Harness, Scale};
use stark::util::table::Table;

fn main() -> anyhow::Result<()> {
    let scale = Scale {
        sizes: vec![1024],
        bs: vec![2, 4, 8, 16, 32],
        backend: BackendKind::Packed,
        executors: 2,
        cores: 2,
        net_bandwidth: Some(1.75e9),
        seed: 7,
        reps: 1,
    };
    let cores = scale.executors * scale.cores;
    let h = Harness::new(scale)?;
    let n = 1024;

    println!("sweeping partition counts for stark, n={n} (Fig. 9's experiment)\n");
    let bs = h.bs_for(Algorithm::Stark, n);
    // Cost-model predictions, normalized to the first b for comparison.
    let preds: Vec<(usize, f64)> =
        bs.iter().map(|&b| (b, cost::stark_cost(n, b, cores).wall(1e-6, 1e-7))).collect();
    let base_pred = preds.first().map(|p| p.1).unwrap_or(1.0);

    let mut t = Table::new(vec!["b", "wall ms", "leaf ms", "leaves", "model (rel)"]);
    let mut best = (0usize, f64::INFINITY);
    for &b in &bs {
        let out = h.run_point(Algorithm::Stark, n, b);
        if out.job.wall_ms < best.1 {
            best = (b, out.job.wall_ms);
        }
        let pred = preds.iter().find(|p| p.0 == b).unwrap().1 / base_pred;
        t.row(vec![
            b.to_string(),
            format!("{:.1}", out.job.wall_ms),
            format!("{:.1}", out.leaf_ms),
            out.leaf_calls.to_string(),
            format!("{pred:.2}x"),
        ]);
    }
    t.print();
    println!("\noptimal partition count: b={} ({:.1} ms)", best.0, best.1);
    println!("(the paper finds the same U-shape; too many partitions for a small matrix hurt)");

    // The planner automates exactly this sweep: ask it instead of
    // measuring. `--splits auto` / `Splits::Auto` runs this resolution
    // inside every session multiply.
    let planner = Planner::new(cores);
    let plan = planner.resolve(Algorithm::Stark, Splits::Auto, n).expect("stark plan");
    println!(
        "planner (default calibration): stark at n={n} should use b={} \
         (predicted {:.1} ms); measured optimum was b={}",
        plan.b,
        plan.predicted_wall_ms(),
        best.0,
    );
    let open = planner.resolve(Algorithm::Auto, Splits::Auto, n).expect("auto plan");
    println!(
        "planner (algorithm open): would run {} with b={} at this scale",
        open.algorithm, open.b
    );
    Ok(())
}
