//! Quickstart: the session API end to end — wrap matrices in handles,
//! let the cost-model planner pick the algorithm and split count, and
//! verify the product.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use stark::algos::Algorithm;
use stark::api::StarkSession;
use stark::cost::Splits;
use stark::engine::ClusterConfig;
use stark::matrix::{matmul_parallel, DenseMatrix};

fn main() -> anyhow::Result<()> {
    // A session owns the simulated cluster (2 executors × 2 cores), the
    // leaf backend (pure-Rust packed GEMM by default; add
    // `.backend_kind(BackendKind::Xla)` for the AOT JAX/Pallas path),
    // and the §IV cost-model planner.
    let session = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build()?;

    // Any shape works — 500 is not a power of two; the session pads to
    // 512 internally and crops the product back.
    let n = 500;
    let a = DenseMatrix::random(n, n, 1);
    let b = DenseMatrix::random(n, n, 2);

    // Ask the planner what it would do before running anything.
    let plan = session.plan(n);
    println!(
        "planner: for n={n} run {} with b={} (padded n={}, predicted {:.1} ms)",
        plan.algorithm,
        plan.b,
        plan.n,
        plan.predicted_wall_ms()
    );

    // Handles distribute lazily and cache their block splits across jobs.
    let ha = session.matrix(&a);
    let hb = session.matrix(&b);

    // Fully automatic multiply: algorithm AND split count by cost model.
    let auto = ha.multiply(&hb).collect()?;
    println!(
        "auto:  {} b={}: wall {:.1} ms, {} leaf products",
        auto.plan.algorithm, auto.plan.b, auto.job.wall_ms, auto.leaf_calls
    );

    // Or pin the paper's system and a split count yourself.
    let pinned =
        ha.multiply(&hb).algorithm(Algorithm::Stark).splits(Splits::Fixed(4)).collect()?;
    println!(
        "stark: b=4: wall {:.1} ms, {} leaf products ({} under the naive block scheme)",
        pinned.job.wall_ms,
        pinned.leaf_calls,
        4 * 4 * 4,
    );

    // Verify both against a single-node product.
    let want = matmul_parallel(&a, &b, 4);
    for (name, out) in [("auto", &auto), ("stark", &pinned)] {
        let diff = want.max_abs_diff(&out.c);
        println!("{name}: max |Δ| vs single-node product = {diff:.3e}");
        anyhow::ensure!(diff < 1e-9, "verification failed");
    }
    println!("OK");
    Ok(())
}
