//! Quickstart: multiply two matrices with Stark on the simulated cluster
//! and verify the product.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use stark::algos::{stark as stark_algo, StarkConfig};
use stark::engine::{ClusterConfig, SparkContext};
use stark::matrix::{matmul_parallel, DenseMatrix};
use stark::runtime::NativeBackend;

fn main() -> anyhow::Result<()> {
    // A 2-executor × 2-core simulated cluster (think: tiny Spark cluster).
    let ctx = SparkContext::new(ClusterConfig::new(2, 2));

    // Two random 512×512 matrices, split into a 4×4 grid of 128-blocks.
    let n = 512;
    let b = 4;
    let a = DenseMatrix::random(n, n, 1);
    let bm = DenseMatrix::random(n, n, 2);

    // Leaf blocks multiply through a backend; use the pure-Rust one here
    // (swap in `stark::config::build_backend(BackendKind::Xla, 2)?` to run
    // the AOT-compiled JAX/Pallas artifacts via PJRT).
    let backend = Arc::new(NativeBackend::default());

    let out = stark_algo::multiply(&ctx, backend, &a, &bm, b, &StarkConfig::default());

    println!(
        "stark multiplied {n}×{n} with b={b}: wall {:.1} ms, {} leaf products \
         ({} would be needed by the naive block scheme)",
        out.job.wall_ms,
        out.leaf_calls,
        b * b * b,
    );

    // Verify against a single-node product.
    let want = matmul_parallel(&a, &bm, 4);
    let diff = want.max_abs_diff(&out.c);
    println!("max |Δ| vs single-node product = {diff:.3e}");
    assert!(diff < 1e-9, "verification failed");
    println!("OK");
    Ok(())
}
