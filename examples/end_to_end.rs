//! End-to-end driver: exercises the **full system** — AOT artifacts
//! (JAX/Pallas → HLO → PJRT), the sparklet engine, all three distributed
//! algorithms, the cost model, and failure recovery — on a real workload,
//! and reports the paper's headline metric (Stark's wall-clock saving
//! over Marlin and MLLib, paper abstract: 28% / 36% at 16384²).
//!
//! Run via `make artifacts` first (the XLA backend loads the artifacts):
//!
//! ```bash
//! cargo run --release --example end_to_end
//! ```

use stark::algos::Algorithm;
use stark::api::StarkSession;
use stark::config::BackendKind;
use stark::engine::{ClusterConfig, FailureSpec};
use stark::experiments::{Harness, Scale};
use stark::matrix::{matmul_parallel, DenseMatrix};
use stark::util::table::Table;

fn main() -> anyhow::Result<()> {
    // Layer check 1: artifacts present (L1/L2 compiled by `make artifacts`).
    let backend_kind = match stark::runtime::find_artifacts_dir() {
        Some(dir) => {
            println!("[1/6] artifacts found at {} (PJRT leaf backend)", dir.display());
            BackendKind::Xla
        }
        None => {
            println!("[1/6] artifacts NOT found — falling back to the native leaf backend");
            println!("      (run `make artifacts` to exercise the JAX/Pallas path)");
            BackendKind::Packed
        }
    };

    // Numerics go through the PJRT/AOT backend when available; the timing
    // sweep below uses the native leaf so measured task times are free of
    // single-host PJRT queueing (EXPERIMENTS.md §Perf discussion).
    let verify_scale = Scale {
        sizes: vec![512],
        bs: vec![4],
        backend: backend_kind,
        executors: 2,
        cores: 2,
        net_bandwidth: None,
        seed: 2024,
        reps: 1,
    };
    let scale = Scale {
        sizes: vec![512, 1024, 2048],
        bs: vec![2, 4, 8, 16],
        backend: stark::config::BackendKind::Packed,
        executors: 2,
        cores: 2,
        net_bandwidth: Some(1.75e9), // the paper's 14 Gb/s InfiniBand
        seed: 2024,
        reps: 2, // min-of-2: stabilizes single-host noise
    };
    let hv = Harness::new(verify_scale)?;
    let h = Harness::new(scale)?;

    // Layer check 2: numerics — every algorithm agrees with the
    // single-node product, through the AOT/PJRT backend when present.
    println!("[2/6] verifying all three systems against the single-node product (n=512, b=4)");
    let (a, bm) = hv.inputs(512);
    let want = matmul_parallel(&a, &bm, 4);
    for algo in Algorithm::ALL {
        let out = hv.run_point(algo, 512, 4);
        let diff = want.max_abs_diff(&out.c);
        println!("      {algo:<7} max |Δ| = {diff:.2e}");
        anyhow::ensure!(diff < 1e-8, "{algo} numerics diverged");
    }

    // Headline experiment: best-b comparison at each size (Fig. 8 method).
    println!("[3/6] headline: fastest wall time per system");
    let mut t = Table::new(vec!["n", "mllib ms", "marlin ms", "stark ms", "vs marlin", "vs mllib"]);
    for &n in &h.scale.sizes.clone() {
        let mut best = std::collections::HashMap::new();
        for algo in Algorithm::ALL {
            let w = h
                .bs_for(algo, n)
                .into_iter()
                .map(|b| h.run_point(algo, n, b).job.wall_ms)
                .fold(f64::INFINITY, f64::min);
            best.insert(algo, w);
        }
        let (ml, ma, st) =
            (best[&Algorithm::Mllib], best[&Algorithm::Marlin], best[&Algorithm::Stark]);
        t.row(vec![
            n.to_string(),
            format!("{ml:.0}"),
            format!("{ma:.0}"),
            format!("{st:.0}"),
            format!("{:+.0}%", (1.0 - st / ma) * 100.0),
            format!("{:+.0}%", (1.0 - st / ml) * 100.0),
        ]);
    }
    t.print();
    println!("      (paper at 16384²: stark 28% under marlin, 36% under mllib)");

    // Layer check 4: fault tolerance — kill a task mid-stage and recover.
    println!("[4/6] failure injection: losing one divide task mid-stage");
    let out = h.run_point_with(Algorithm::Stark, 512, 4, |c| {
        c.failure = Some(FailureSpec { stage_contains: "divide".into(), partition: 0 });
    });
    let retries: u32 = out.job.stages.iter().map(|s| s.retries).sum();
    anyhow::ensure!(retries == 1, "expected exactly one retry, saw {retries}");
    let diff = want_for(&h, 512).max_abs_diff(&out.c);
    anyhow::ensure!(diff < 1e-8, "post-recovery product wrong");
    println!("      recovered via lineage recomputation, product still exact (Δ={diff:.1e})");

    // Layer check 5: the planner closes the loop — auto-selection
    // through the session API picks a concrete system and split count
    // and the product stays exact.
    println!("[5/6] cost-model planner: auto algorithm + splits through the session API");
    let session = StarkSession::builder().cluster(ClusterConfig::new(2, 2)).build()?;
    for n in [512usize, 2048, 16384] {
        let plan = session.plan(n);
        println!(
            "      plan(n={n}): {} with b={} (predicted {:.0} ms)",
            plan.algorithm,
            plan.b,
            plan.predicted_wall_ms()
        );
    }
    let (pa, pb) = hv.inputs(512);
    let auto = session.matrix(&pa).multiply(&session.matrix(&pb)).collect()?;
    let diff = matmul_parallel(&pa, &pb, 4).max_abs_diff(&auto.c);
    anyhow::ensure!(diff < 1e-8, "auto-planned product diverged");
    println!(
        "      executed auto plan: {} b={} — exact (Δ={diff:.1e})",
        auto.plan.algorithm, auto.plan.b
    );

    // Layer check 6: the leaf-count law that explains the headline.
    println!("[6/6] leaf-multiplication law (the paper's core argument):");
    for b in [2usize, 4, 8] {
        let stark = h.run_point(Algorithm::Stark, 512, b).leaf_calls;
        let marlin = h.run_point(Algorithm::Marlin, 512, b).leaf_calls;
        println!(
            "      b={b}: stark {stark} = 7^log2(b) vs marlin {marlin} = b³  (ratio {:.2})",
            marlin as f64 / stark as f64
        );
    }
    println!("\nend-to-end driver completed — all layers compose.");
    Ok(())
}

fn want_for(h: &Harness, n: usize) -> DenseMatrix {
    let (a, bm) = h.inputs(n);
    matmul_parallel(&a, &bm, 4)
}
